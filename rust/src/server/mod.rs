//! A dependency-free HTTP/1.1 JSON frontend over the
//! [`crate::api::Service`] facade (DESIGN.md §10).
//!
//! One warm process, one [`AccelConfig`], one shared plan cache, one
//! [`ArtifactCache`] of rendered responses — so a fleet of clients
//! sweeping layer geometries pays for each distinct plan once and for
//! each repeated request nothing at all. The layering is deliberately
//! boring:
//!
//! * [`http`] — request framing (request line, headers, `Content-Length`
//!   bodies, keep-alive) with hard size limits; hostile input maps to
//!   4xx, never to a dead worker. Parsing is incremental
//!   ([`http::try_parse`]) so both frontends share one grammar.
//! * [`conn`] — the per-connection state machine
//!   (`Reading → Dispatching → Writing → KeepAlive/Closing`) over any
//!   `Read + Write` transport, with partial-I/O buffers and deadlines.
//! * `event_loop` (private) — the default frontend: one nonblocking
//!   readiness loop multiplexing every connection, shedding overload
//!   with `429 Too Many Requests` + `Retry-After`.
//! * [`router`] — the closed `(method, path)` table.
//! * [`executor`] — the dispatch seam: CPU-bound request work runs
//!   behind the [`executor::Executor`] trait.
//! * [`pool`] — the production [`executor::Executor`]: a bounded worker
//!   pool whose queue bound backpressures the legacy accept loop and
//!   enforces the event loop's shed policy.
//! * [`cache`] — rendered-response memoization keyed by
//!   [`SimRequest`] (`Copy + Eq + Hash`).
//! * [`metrics`] — per-route counters and latency histograms, the
//!   event-loop series (open connections, sheds, stalls), plus the
//!   plan/artifact cache counters, in Prometheus text format.
//! * [`chaos`] — fault-injection transports ([`chaos::MemStream`],
//!   [`chaos::ChaosStream`]) for hostile-I/O tests; never constructed
//!   by the live server.
//!
//! Two frontends serve the same routes with byte-identical responses
//! (asserted in `tests/server.rs`): [`Frontend::EventLoop`] (default)
//! and [`Frontend::BlockingPool`], the original
//! thread-per-connection loop, kept as the A/B baseline.
//!
//! Everything is `std` only — the offline build has no crate registry,
//! and nothing here needs one: the protocol subset is small enough that
//! owning it outright is less code than binding a framework would be.
//!
//! # Routes
//!
//! | Route | Answer |
//! |---|---|
//! | `POST /v1/query` | One [`SimRequest`] body → the same bytes [`crate::api::render_all_json`] prints in-process |
//! | `POST /v1/batch` | `{"requests":[...]}` → per-item results (`207` when any item fails) |
//! | `GET /v1/requests` | Machine-readable request catalog |
//! | `GET /healthz` | Liveness + request count |
//! | `GET /metrics` | Prometheus text: routes, latencies, cache counters |
//! | `POST /v1/shutdown` | Graceful shutdown sentinel (drains, then exits) |
//!
//! # Example
//!
//! ```no_run
//! use bp_im2col::accel::AccelConfig;
//! use bp_im2col::server::Server;
//!
//! let server = Server::bind(AccelConfig::default(), "127.0.0.1:0", 4).unwrap();
//! println!("listening on {}", server.local_addr());
//! server.serve().unwrap(); // returns after POST /v1/shutdown
//! ```

pub mod cache;
pub mod chaos;
pub mod conn;
mod event_loop;
pub mod executor;
pub mod http;
pub mod metrics;
pub mod pool;
pub mod router;

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::accel::AccelConfig;
use crate::api::artifact::json_string;
use crate::api::json::{self, parse_batch};
use crate::api::{render_all_json, Service, SimRequest};
use cache::ArtifactCache;
use conn::ConnConfig;
use http::{HttpConn, Request, Response};
use metrics::ServerMetrics;
use pool::ThreadPool;
use router::Route;

/// Address `serve` binds when `--addr` is not given.
pub const DEFAULT_ADDR: &str = "127.0.0.1:8000";

/// Connection cap of the event-loop frontend when `--max-conns` is not
/// given; connections over the cap are answered `429` and closed.
pub const DEFAULT_MAX_CONNS: usize = 1024;

/// `Retry-After` seconds advertised on shed (`429`) responses.
pub const RETRY_AFTER_SECS: u64 = 1;

/// Per-connection socket read timeout: bounds how long an idle
/// keep-alive connection can pin a worker (notably during shutdown
/// drain).
const READ_TIMEOUT: Duration = Duration::from_secs(5);

/// Default worker-thread count for [`Server::bind`] callers that take
/// the platform default (one per core, capped — same policy as the
/// scheduler's host workers).
pub fn default_threads() -> usize {
    crate::coordinator::scheduler::default_workers()
}

/// Which serving core drives the listener.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Frontend {
    /// The nonblocking readiness loop with overload shedding (default).
    EventLoop,
    /// The original thread-per-connection blocking loop, kept as the
    /// A/B baseline: same routes, byte-identical responses.
    BlockingPool,
}

/// Tunables of one server instance (`repro serve` flags).
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Worker threads running CPU-bound request work.
    pub threads: usize,
    /// Event loop only: connections admitted before new ones are shed.
    pub max_conns: usize,
    /// Event loop only: dispatches allowed beyond busy workers before
    /// requests are shed (also the worker pool's queue bound).
    pub shed_queue: usize,
    /// Which serving core drives the listener.
    pub frontend: Frontend,
    /// Event loop only: per-connection deadlines.
    pub conn: ConnConfig,
}

impl ServeOptions {
    /// Defaults for `threads` workers: event-loop frontend, a shed
    /// queue of `2 * threads` (matching [`ThreadPool::new`]'s bound),
    /// and [`DEFAULT_MAX_CONNS`].
    pub fn for_threads(threads: usize) -> Self {
        let threads = threads.max(1);
        ServeOptions {
            threads,
            max_conns: DEFAULT_MAX_CONNS,
            shed_queue: 2 * threads,
            frontend: Frontend::EventLoop,
            conn: ConnConfig::default(),
        }
    }
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self::for_threads(default_threads())
    }
}

/// Shared state of one running server.
struct ServerState {
    service: Service,
    artifacts: ArtifactCache,
    metrics: ServerMetrics,
    shutdown: AtomicBool,
    local_addr: SocketAddr,
}

/// The HTTP frontend: owns the listener, the serving core, the
/// [`Service`] and both caches.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    opts: ServeOptions,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:8000`, port `0` for ephemeral) with
    /// default options for `threads` workers over a service for `cfg`.
    pub fn bind(cfg: AccelConfig, addr: &str, threads: usize) -> io::Result<Server> {
        Self::bind_with(cfg, addr, ServeOptions::for_threads(threads))
    }

    /// Bind `addr` with explicit [`ServeOptions`] — the full-control
    /// constructor behind `repro serve`'s flags and the A/B tests.
    pub fn bind_with(cfg: AccelConfig, addr: &str, opts: ServeOptions) -> io::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local_addr = listener.local_addr()?;
        let state = Arc::new(ServerState {
            service: Service::new(cfg),
            artifacts: ArtifactCache::new(),
            metrics: ServerMetrics::new(),
            shutdown: AtomicBool::new(false),
            local_addr,
        });
        let opts = ServeOptions { threads: opts.threads.max(1), ..opts };
        Ok(Server { listener, state, opts })
    }

    /// The bound address (the actual port when `:0` was requested).
    pub fn local_addr(&self) -> SocketAddr {
        self.state.local_addr
    }

    /// Serve connections until a `POST /v1/shutdown` arrives, then
    /// drain in-flight work and return. Signal-free by design: the
    /// sentinel route sets the shutdown flag; the event loop observes
    /// it on its next tick, while the blocking frontend pokes its
    /// accept loop with a loopback connection.
    pub fn serve(self) -> io::Result<()> {
        match self.opts.frontend {
            Frontend::EventLoop => self.serve_event_loop(),
            Frontend::BlockingPool => self.serve_blocking(),
        }
    }

    /// The readiness-loop frontend: parse and frame on one thread,
    /// dispatch CPU-bound work to the bounded pool, shed overload.
    fn serve_event_loop(self) -> io::Result<()> {
        let pool = ThreadPool::with_queue(self.opts.threads, self.opts.shed_queue);
        event_loop::run(self.listener, self.state, Box::new(pool), self.opts)
    }

    /// The legacy thread-per-connection frontend.
    fn serve_blocking(self) -> io::Result<()> {
        let pool = ThreadPool::new(self.opts.threads);
        for stream in self.listener.incoming() {
            if self.state.shutdown.load(Ordering::Acquire) {
                break;
            }
            match stream {
                Ok(stream) => {
                    let state = Arc::clone(&self.state);
                    pool.execute(move || handle_connection(stream, &state));
                }
                // Transient accept errors (aborted handshake, fd
                // pressure): keep serving, but back off briefly so
                // persistent failure (EMFILE) cannot busy-spin a core.
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(20));
                    continue;
                }
            }
        }
        drop(self.listener);
        pool.join();
        Ok(())
    }
}

/// Serve one connection: a keep-alive loop of read → route → respond.
/// Parse failures answer with their 4xx/5xx and close; transport errors
/// just close. Never panics the worker — handler panics are caught per
/// request inside [`Service::try_run`].
fn handle_connection(stream: TcpStream, state: &Arc<ServerState>) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut conn = HttpConn::new(&stream);
    loop {
        match conn.read_request() {
            Ok(None) => break, // peer finished its keep-alive session
            Ok(Some(req)) => {
                let start = Instant::now();
                let (route, response) = handle_request(&req, state);
                let elapsed_us = start.elapsed().as_micros().min(u64::MAX as u128) as u64;
                // Unresolved routes land in the "other" series — hostile
                // traffic stays visible in /metrics.
                state.metrics.record(route, response.status, elapsed_us);
                let shutting_down = state.shutdown.load(Ordering::Acquire);
                // Application-level errors (a 400 for a typo'd request
                // kind, a 404) leave the stream consistently framed, so
                // the keep-alive session continues; only framing errors
                // (the Err arm below) desync the stream and must close.
                let keep = req.keep_alive() && !shutting_down;
                let is_shutdown = route == Some(Route::Shutdown);
                if is_shutdown {
                    // Wake the accept loop so it observes the flag even
                    // with no other traffic in flight — before (and
                    // regardless of) the response write, so a client
                    // that resets the connection cannot strand serve()
                    // in accept() with the flag already set.
                    let _ = TcpStream::connect(wake_addr(state.local_addr));
                }
                if conn.write_response(&response, keep).is_err() || is_shutdown || !keep {
                    break;
                }
            }
            Err(err) => {
                if let Some(response) = err.response() {
                    state.metrics.record(None, response.status, 0);
                    let _ = conn.write_response(&response, false);
                }
                break;
            }
        }
    }
}

/// Where to connect to wake the accept loop: the bound address, except
/// that a wildcard bind (`0.0.0.0` / `[::]`) is not a connectable
/// destination everywhere, so it is replaced by the matching loopback.
fn wake_addr(addr: SocketAddr) -> SocketAddr {
    use std::net::{IpAddr, Ipv4Addr, Ipv6Addr};
    let ip = match addr.ip() {
        IpAddr::V4(ip) if ip.is_unspecified() => IpAddr::V4(Ipv4Addr::LOCALHOST),
        IpAddr::V6(ip) if ip.is_unspecified() => IpAddr::V6(Ipv6Addr::LOCALHOST),
        ip => ip,
    };
    SocketAddr::new(ip, addr.port())
}

/// Dispatch one parsed request. Returns the route (when one resolved —
/// used for metrics) and the response.
fn handle_request(req: &Request, state: &Arc<ServerState>) -> (Option<Route>, Response) {
    let route = match Route::resolve(req) {
        Ok(route) => route,
        Err(response) => return (None, response),
    };
    let response = match route {
        Route::Healthz => Response::json(
            200,
            format!(
                "{{\"status\":\"ok\",\"requests_served\":{}}}",
                state.metrics.requests_total()
            ),
        ),
        Route::Metrics => Response::text(
            200,
            state.metrics.render(
                &state.service.plan_cache().stats(),
                &state.artifacts.stats(),
                &crate::trace::profile::snapshot(),
            ),
        ),
        Route::Requests => Response::json(200, json::request_catalog_json()),
        Route::Query => handle_query(&req.body, state),
        Route::Batch => handle_batch(&req.body, state),
        Route::Shutdown => {
            state.shutdown.store(true, Ordering::Release);
            Response::json(200, "{\"status\":\"shutting down\"}")
        }
    };
    (Some(route), response)
}

/// `POST /v1/query`: decode one request, serve it through the artifact
/// cache. The success body is byte-identical to
/// [`crate::api::render_all_json`] over an in-process
/// [`Service::run`] — asserted for every request kind in
/// `tests/server.rs`.
fn handle_query(body: &[u8], state: &Arc<ServerState>) -> Response {
    let text = match std::str::from_utf8(body) {
        Ok(text) => text,
        Err(_) => return Response::error(400, "request body is not UTF-8"),
    };
    let req = match SimRequest::from_json(text) {
        Ok(req) => req,
        Err(msg) => return Response::error(400, &msg),
    };
    if let Err(msg) = req.validate() {
        return Response::error(400, &msg);
    }
    match serve_cached(req, state) {
        Ok(rendered) => Response::json(200, rendered.as_bytes().to_vec()),
        // Validation passed, so a failure here is the panic backstop.
        Err(err) => Response::error(500, &err.to_string()),
    }
}

/// Serve one validated request through the artifact cache.
///
/// Caching keys by [`SimRequest::cache_key`], which normalizes
/// evaluation-environmental knobs away (a DSE sweep's `devices` thread
/// count changes no byte of the response), so repeats hit regardless
/// of how the client parallelized the first run.
fn serve_cached(
    req: SimRequest,
    state: &Arc<ServerState>,
) -> Result<Arc<String>, crate::api::RequestError> {
    // Wall-clock telemetry (`profile`) is never cached: its bytes are
    // fresh measurements by definition (DESIGN.md §16's two-clock rule).
    if !req.cacheable() {
        let artifacts = state.service.try_run(&req)?;
        return Ok(Arc::new(render_all_json(&artifacts)));
    }
    let key = req.cache_key();
    if let Some(rendered) = state.artifacts.get(&key) {
        return Ok(rendered);
    }
    let artifacts = state.service.try_run(&req)?;
    Ok(state.artifacts.insert(key, render_all_json(&artifacts)))
}

/// `POST /v1/batch`: decode `{"requests":[...]}`, serve the decodable
/// items concurrently through [`Service::run_batch`] (misses only; hits
/// come from the artifact cache), and answer per item — `200` when all
/// succeeded, `207` when any item failed. Item `i` of `results` is
/// either the same JSON document `/v1/query` would return for that
/// request or `{"error":...}`.
fn handle_batch(body: &[u8], state: &Arc<ServerState>) -> Response {
    let text = match std::str::from_utf8(body) {
        Ok(text) => text,
        Err(_) => return Response::error(400, "request body is not UTF-8"),
    };
    let decoded = match parse_batch(text) {
        Ok(decoded) => decoded,
        Err(msg) => return Response::error(400, &msg),
    };

    // Per-item outcome slots; decode errors fill theirs immediately.
    let mut slots: Vec<Result<Arc<String>, String>> = decoded
        .iter()
        .map(|item| match item {
            Ok(_) => Err(String::new()), // placeholder, filled below
            Err(msg) => Err(format!("bad request: {msg}")),
        })
        .collect();

    // Artifact-cache pass, then one concurrent run_batch over the
    // *distinct* misses — N copies of the same request in one batch run
    // the model once and fan the result back out to every copy's slot.
    // Distinctness is by [`SimRequest::cache_key`], so items differing
    // only in evaluation-environmental knobs (a DSE `devices` value)
    // also collapse to one run.
    let mut miss_reqs: Vec<SimRequest> = Vec::new();
    let mut miss_of: std::collections::HashMap<SimRequest, usize> = std::collections::HashMap::new();
    let mut pending: Vec<(usize, usize)> = Vec::new(); // (slot, miss index)
    for (i, item) in decoded.iter().enumerate() {
        if let Ok(req) = item {
            // Uncacheable telemetry (`profile`) neither reads nor joins
            // the cache — every copy in the batch measures afresh.
            if !req.cacheable() {
                miss_reqs.push(*req);
                pending.push((i, miss_reqs.len() - 1));
                continue;
            }
            let key = req.cache_key();
            if let Some(rendered) = state.artifacts.get(&key) {
                slots[i] = Ok(rendered);
                continue;
            }
            // Execute the *original* request (the first one to miss for
            // this key), so a DSE item's devices lowering is honored
            // during evaluation — same contract as /v1/query — while
            // the response is cached under the normalized key.
            let mi = *miss_of.entry(key).or_insert_with(|| {
                miss_reqs.push(*req);
                miss_reqs.len() - 1
            });
            pending.push((i, mi));
        }
    }
    let results = state.service.run_batch(&miss_reqs);
    let rendered: Vec<Result<Arc<String>, String>> = miss_reqs
        .iter()
        .zip(results)
        .map(|(req, result)| match result {
            Ok(artifacts) if !req.cacheable() => Ok(Arc::new(render_all_json(&artifacts))),
            Ok(artifacts) => {
                Ok(state.artifacts.insert(req.cache_key(), render_all_json(&artifacts)))
            }
            Err(err) => Err(err.to_string()),
        })
        .collect();
    for (slot, mi) in pending {
        slots[slot] = rendered[mi].clone();
    }

    let any_failed = slots.iter().any(|s| s.is_err());
    let mut out = String::from("{\"results\":[");
    for (i, slot) in slots.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        match slot {
            Ok(rendered) => out.push_str(rendered),
            Err(msg) => {
                out.push_str("{\"error\":");
                out.push_str(&json_string(msg));
                out.push('}');
            }
        }
    }
    out.push_str("]}");
    Response::json(if any_failed { 207 } else { 200 }, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> Arc<ServerState> {
        Arc::new(ServerState {
            service: Service::new(AccelConfig::default()),
            artifacts: ArtifactCache::new(),
            metrics: ServerMetrics::new(),
            shutdown: AtomicBool::new(false),
            local_addr: "127.0.0.1:0".parse().unwrap(),
        })
    }

    fn body_str(r: &Response) -> &str {
        std::str::from_utf8(&r.body).unwrap()
    }

    #[test]
    fn query_serves_the_in_process_bytes_and_then_the_cache() {
        let st = state();
        let resp = handle_query(b"{\"kind\":\"table3\"}", &st);
        assert_eq!(resp.status, 200);
        let direct = render_all_json(&st.service.run(&SimRequest::Table3));
        assert_eq!(body_str(&resp), direct);
        // Second hit comes from the artifact cache.
        let again = handle_query(b"{\"kind\":\"table3\"}", &st);
        assert_eq!(body_str(&again), direct);
        let cache = st.artifacts.stats();
        assert_eq!((cache.hits, cache.misses, cache.entries), (1, 1, 1));
    }

    #[test]
    fn query_errors_are_4xx_json() {
        let st = state();
        assert_eq!(handle_query(b"\xff\xfe", &st).status, 400);
        assert_eq!(handle_query(b"not json", &st).status, 400);
        assert_eq!(handle_query(b"{\"kind\":\"nope\"}", &st).status, 400);
        // Decodes but fails validation (groups do not divide channels).
        let resp =
            handle_query(b"{\"kind\":\"layer\",\"spec\":\"56/100/100/3/2/1/g32\"}", &st);
        assert_eq!(resp.status, 400);
        assert!(body_str(&resp).contains("error"), "{}", body_str(&resp));
    }

    #[test]
    fn batch_answers_per_item_with_207_on_partial_failure() {
        let st = state();
        let body = b"{\"requests\":[{\"kind\":\"table3\"},{\"kind\":\"nope\"},{\"kind\":\"table4\"}]}";
        let resp = handle_batch(body, &st);
        assert_eq!(resp.status, 207);
        let text = body_str(&resp);
        assert!(text.starts_with("{\"results\":["), "{text}");
        assert!(text.contains("\"error\":\"bad request:"), "{text}");
        let t3 = render_all_json(&st.service.run(&SimRequest::Table3));
        let t4 = render_all_json(&st.service.run(&SimRequest::Table4));
        assert!(text.contains(&t3), "{text}");
        assert!(text.contains(&t4), "{text}");
        // All-good batches are plain 200.
        let resp = handle_batch(b"{\"requests\":[{\"kind\":\"table2\"}]}", &st);
        assert_eq!(resp.status, 200);
        // And batch results landed in the artifact cache: re-query hits.
        let cached = handle_query(b"{\"kind\":\"table4\"}", &st);
        assert_eq!(body_str(&cached), t4);
        assert!(st.artifacts.stats().hits >= 1);
    }

    #[test]
    fn batch_runs_identical_requests_once_and_fans_out() {
        let st = state();
        let spec = "{\"kind\":\"layer\",\"spec\":\"56/128/128/3/2/1\"}";
        let body = format!("{{\"requests\":[{spec},{spec},{spec}]}}");
        let resp = handle_batch(body.as_bytes(), &st);
        assert_eq!(resp.status, 200);
        let req = SimRequest::from_json(spec).unwrap();
        let doc = render_all_json(&st.service.run(&req));
        // The comparison run above replays the cache, so subtract its
        // lookups: the *batch* must have planned the layer exactly once
        // (4 lookups = 2 passes x 2 modes), not once per copy.
        let stats = st.service.plan_cache().stats();
        assert_eq!(stats.misses, 4, "{stats:?}");
        assert_eq!(stats.lookups(), 8, "batch once + comparison run: {stats:?}");
        assert_eq!(body_str(&resp), format!("{{\"results\":[{doc},{doc},{doc}]}}"));
        assert_eq!(st.artifacts.stats().entries, 1);
    }

    #[test]
    fn dse_queries_cache_across_devices_values() {
        // `devices` is evaluation parallelism, not semantics: the same
        // sweep at a different thread count must be a cache hit, not a
        // recomputation (and not a second cache entry).
        let st = state();
        let a = handle_query(b"{\"kind\":\"dse\",\"budget\":4,\"seed\":7,\"devices\":2}", &st);
        assert_eq!(a.status, 200);
        let b = handle_query(b"{\"kind\":\"dse\",\"budget\":4,\"seed\":7,\"devices\":1}", &st);
        assert_eq!(body_str(&b), body_str(&a));
        let cache = st.artifacts.stats();
        assert_eq!((cache.hits, cache.misses, cache.entries), (1, 1, 1));
    }

    #[test]
    fn trace_caches_but_profile_never_does() {
        let st = state();
        // Trace is deterministic virtual time: repeats are cache hits
        // and a different `devices` value is the *same* cache entry.
        let a = handle_query(b"{\"kind\":\"trace\"}", &st);
        assert_eq!(a.status, 200);
        let b = handle_query(b"{\"kind\":\"trace\",\"devices\":2}", &st);
        assert_eq!(body_str(&b), body_str(&a));
        let cache = st.artifacts.stats();
        assert_eq!((cache.hits, cache.misses, cache.entries), (1, 1, 1));
        // Profile is wall-clock telemetry: 200, but never cached.
        let p = handle_query(b"{\"kind\":\"profile\"}", &st);
        assert_eq!(p.status, 200);
        assert!(body_str(&p).contains("plan_builds_per_sec"), "{}", body_str(&p));
        let cache = st.artifacts.stats();
        assert_eq!(cache.entries, 1, "profile joined the cache: {cache:?}");
        // Same through the batch path: no new cache entries, and the
        // batch still answers per item.
        let resp = handle_batch(b"{\"requests\":[{\"kind\":\"profile\"}]}", &st);
        assert_eq!(resp.status, 200);
        assert_eq!(st.artifacts.stats().entries, 1);
    }

    #[test]
    fn unknown_route_and_method_reach_the_router_answers() {
        let st = state();
        let req = Request {
            method: "GET".into(),
            path: "/nope".into(),
            http10: false,
            headers: vec![],
            body: vec![],
        };
        let (route, resp) = handle_request(&req, &st);
        assert_eq!(route, None);
        assert_eq!(resp.status, 404);
    }

    #[test]
    fn wake_addr_replaces_wildcard_binds_with_loopback() {
        let w = |s: &str| wake_addr(s.parse().unwrap()).to_string();
        assert_eq!(w("0.0.0.0:8000"), "127.0.0.1:8000");
        assert_eq!(w("[::]:8000"), "[::1]:8000");
        assert_eq!(w("127.0.0.1:9000"), "127.0.0.1:9000");
        assert_eq!(w("192.168.1.5:80"), "192.168.1.5:80");
    }

    #[test]
    fn shutdown_route_sets_the_flag() {
        let st = state();
        let req = Request {
            method: "POST".into(),
            path: "/v1/shutdown".into(),
            http10: false,
            headers: vec![],
            body: vec![],
        };
        let (route, resp) = handle_request(&req, &st);
        assert_eq!(route, Some(Route::Shutdown));
        assert_eq!(resp.status, 200);
        assert!(st.shutdown.load(Ordering::Acquire));
    }

    #[test]
    fn healthz_and_metrics_render() {
        let st = state();
        let req = Request {
            method: "GET".into(),
            path: "/healthz".into(),
            http10: false,
            headers: vec![],
            body: vec![],
        };
        let (route, resp) = handle_request(&req, &st);
        assert_eq!(resp.status, 200);
        assert!(body_str(&resp).contains("\"status\":\"ok\""));
        // The connection loop records after dispatch; emulate it here.
        st.metrics.record(route, resp.status, 10);
        let req = Request { path: "/metrics".into(), ..req };
        let (_, resp) = handle_request(&req, &st);
        assert!(body_str(&resp).contains("bp_plan_cache_entries"));
        assert!(body_str(&resp).contains("bp_server_requests_total{route=\"healthz\"} 1"));
    }
}
