//! Pluggable request-dispatch strategies for the serving frontends.
//!
//! The event loop parses requests on one thread but runs the CPU-bound
//! [`crate::api::Service`] work elsewhere; *where* is behind the
//! [`Executor`] trait. The production strategy is the bounded
//! [`crate::server::pool::ThreadPool`]; [`InlineExecutor`] runs jobs on
//! the caller thread for deterministic single-threaded tests. Keeping
//! the seam this narrow is what lets `tests/server.rs` A/B the legacy
//! blocking frontend against the event loop byte-for-byte.

/// One queued unit of request work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

/// A scheduling strategy for CPU-bound request work.
pub trait Executor: Send {
    /// Queue `job` without blocking. A saturated executor hands the job
    /// back so the caller can shed the request instead of stalling.
    fn try_spawn(&self, job: Job) -> Result<(), Job>;

    /// Queue `job`, waiting for room (the blocking frontend's
    /// backpressure toward its accept loop).
    fn spawn(&self, job: Job);

    /// Worker threads executing jobs; `0` means jobs run on the caller.
    fn workers(&self) -> usize;

    /// Stop accepting work, run every already-queued job, and join.
    fn join(self: Box<Self>);
}

/// Runs every job inline on the calling thread. Deterministic — jobs
/// finish before `try_spawn`/`spawn` returns — which makes event-loop
/// unit tests single-threaded and schedule-free.
pub struct InlineExecutor;

impl Executor for InlineExecutor {
    fn try_spawn(&self, job: Job) -> Result<(), Job> {
        job();
        Ok(())
    }

    fn spawn(&self, job: Job) {
        job();
    }

    fn workers(&self) -> usize {
        0
    }

    fn join(self: Box<Self>) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn inline_executor_runs_jobs_immediately() {
        let counter = Arc::new(AtomicUsize::new(0));
        let exec = InlineExecutor;
        let c = Arc::clone(&counter);
        assert!(exec.try_spawn(Box::new(move || {
            c.fetch_add(1, Ordering::Relaxed);
        }))
        .is_ok());
        assert_eq!(counter.load(Ordering::Relaxed), 1, "ran before try_spawn returned");
        let c = Arc::clone(&counter);
        exec.spawn(Box::new(move || {
            c.fetch_add(1, Ordering::Relaxed);
        }));
        assert_eq!(counter.load(Ordering::Relaxed), 2);
        assert_eq!(exec.workers(), 0);
        Box::new(exec).join();
    }
}
