//! Server observability: per-route request counters, status classes and
//! latency histograms, rendered in the Prometheus text exposition
//! format (plus the plan- and artifact-cache counters) at `/metrics`.
//!
//! Lock-free on the hot path: every series is an [`AtomicU64`], bumped
//! once per response. The histogram buckets are cumulative (`le`
//! semantics), fixed at microsecond bounds that bracket the server's
//! realistic range — a cached hit is tens of microseconds, a cold
//! 8-device fleet sweep tens of milliseconds.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::accel::plan::PlanCacheStats;
use crate::accel::strategy::LoweringStrategy;
use crate::server::cache::ArtifactCacheStats;
use crate::server::router::Route;
use crate::trace::profile::{Phase, ProfileSnapshot, BUCKETS, NS_BUCKETS};

/// Upper bounds of the latency histogram buckets, in microseconds
/// (a final implicit `+Inf` bucket follows).
pub const LATENCY_BUCKETS_US: [u64; 8] =
    [100, 500, 1_000, 5_000, 10_000, 50_000, 100_000, 1_000_000];

/// One phase of serving a request, bracketed by the request-scoped
/// spans in `server/conn.rs` (`parse` → `dispatch` → `write`; the
/// render step is inside `dispatch`, which is where the model runs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerPhase {
    /// First request byte read → request fully parsed.
    Parse,
    /// Parsed request → response bytes rendered and queued.
    Dispatch,
    /// First response byte queued → last byte flushed to the socket.
    Write,
}

impl ServerPhase {
    /// Every phase, in series-rendering order.
    pub const ALL: [ServerPhase; 3] =
        [ServerPhase::Parse, ServerPhase::Dispatch, ServerPhase::Write];

    /// Stable `phase` label value.
    pub fn label(self) -> &'static str {
        match self {
            ServerPhase::Parse => "parse",
            ServerPhase::Dispatch => "dispatch",
            ServerPhase::Write => "write",
        }
    }

    fn index(self) -> usize {
        match self {
            ServerPhase::Parse => 0,
            ServerPhase::Dispatch => 1,
            ServerPhase::Write => 2,
        }
    }
}

/// Histogram counters of one request-serving phase.
#[derive(Default)]
struct PhaseMetrics {
    /// Observations (also the histogram count).
    count: AtomicU64,
    /// Cumulative-style histogram counts, one per bucket plus `+Inf`.
    buckets: [AtomicU64; LATENCY_BUCKETS_US.len() + 1],
    /// Total phase time, microseconds.
    sum_us: AtomicU64,
}

/// Counters of one route.
#[derive(Default)]
struct RouteMetrics {
    /// Requests served (also the histogram count).
    requests: AtomicU64,
    /// Responses by status class: 2xx, 4xx, 5xx (3xx never emitted).
    classes: [AtomicU64; 3],
    /// Cumulative-style histogram counts, one per bucket plus `+Inf`.
    buckets: [AtomicU64; LATENCY_BUCKETS_US.len() + 1],
    /// Total latency, microseconds.
    sum_us: AtomicU64,
}

/// All server metrics; one instance lives for the server's lifetime.
pub struct ServerMetrics {
    /// One slot per [`Route`] plus a final `other` slot for responses
    /// that never resolved a route (404/405, framing 4xx/5xx) — hostile
    /// traffic must be visible, not invisible, in `/metrics`.
    routes: Vec<RouteMetrics>,
    /// Connections accepted, including ones shed at the cap.
    accepted: AtomicU64,
    /// Connections admitted past the cap check (gauge numerator).
    opened: AtomicU64,
    /// Admitted connections since closed (gauge denominator).
    closed: AtomicU64,
    /// Requests (or whole connections) answered `429` by overload
    /// shedding.
    shed: AtomicU64,
    /// Reads that moved bytes but left a request incomplete — a measure
    /// of drip-fed (slowloris-shaped) traffic.
    read_stalls: AtomicU64,
    /// Writes that moved bytes but could not finish a response — the
    /// peer's receive window is the bottleneck.
    write_stalls: AtomicU64,
    /// Connections closed by a read/write deadline, not by the peer.
    deadline_closes: AtomicU64,
    /// Per-phase request-span histograms (parse / dispatch / write).
    phases: [PhaseMetrics; 3],
}

/// Series label of the unrouted-response slot.
pub const OTHER_LABEL: &str = "other";

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    /// Zeroed metrics for every route (plus the `other` slot).
    pub fn new() -> Self {
        ServerMetrics {
            routes: (0..Route::ALL.len() + 1).map(|_| RouteMetrics::default()).collect(),
            accepted: AtomicU64::new(0),
            opened: AtomicU64::new(0),
            closed: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            read_stalls: AtomicU64::new(0),
            write_stalls: AtomicU64::new(0),
            deadline_closes: AtomicU64::new(0),
            phases: [PhaseMetrics::default(), PhaseMetrics::default(), PhaseMetrics::default()],
        }
    }

    /// Record one request-scoped phase span (parse / dispatch / write).
    pub fn record_phase(&self, phase: ServerPhase, elapsed_us: u64) {
        let m = &self.phases[phase.index()];
        m.count.fetch_add(1, Ordering::Relaxed);
        let bucket = LATENCY_BUCKETS_US
            .iter()
            .position(|&le| elapsed_us <= le)
            .unwrap_or(LATENCY_BUCKETS_US.len());
        m.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        m.sum_us.fetch_add(elapsed_us, Ordering::Relaxed);
    }

    /// Count one accepted TCP connection (admitted or shed).
    pub fn conn_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one connection admitted into the event loop.
    pub fn conn_opened(&self) {
        self.opened.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one admitted connection leaving the event loop.
    pub fn conn_closed(&self) {
        self.closed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one request (or over-cap connection) shed with a `429`.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests shed so far (used by the shed tests).
    pub fn shed_total(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Count a read that progressed without completing a request.
    pub fn record_read_stall(&self) {
        self.read_stalls.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a write that progressed without finishing the response.
    pub fn record_write_stall(&self) {
        self.write_stalls.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a connection closed by a deadline (idle closes excluded).
    pub fn record_deadline_close(&self) {
        self.deadline_closes.fetch_add(1, Ordering::Relaxed);
    }

    /// `(slot index, series label)` of every slot, in slot order.
    fn labels() -> impl Iterator<Item = (usize, &'static str)> {
        Route::ALL.iter().map(|r| r.label()).chain(std::iter::once(OTHER_LABEL)).enumerate()
    }

    /// Record one served response. `None` is the unrouted slot —
    /// resolver 404/405s and request-framing errors.
    pub fn record(&self, route: Option<Route>, status: u16, elapsed_us: u64) {
        let index = route.map_or(Route::ALL.len(), |r| r.index());
        let m = &self.routes[index];
        m.requests.fetch_add(1, Ordering::Relaxed);
        let class = match status {
            200..=299 => 0,
            400..=499 => 1,
            _ => 2,
        };
        m.classes[class].fetch_add(1, Ordering::Relaxed);
        let bucket = LATENCY_BUCKETS_US
            .iter()
            .position(|&le| elapsed_us <= le)
            .unwrap_or(LATENCY_BUCKETS_US.len());
        m.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        m.sum_us.fetch_add(elapsed_us, Ordering::Relaxed);
    }

    /// Total requests served across every route.
    pub fn requests_total(&self) -> u64 {
        self.routes.iter().map(|m| m.requests.load(Ordering::Relaxed)).sum()
    }

    /// Render the Prometheus text exposition, folding in the model-side
    /// cache counters and the host profiler snapshot (wall-clock
    /// telemetry — the virtual-time trace artifact never feeds this).
    pub fn render(
        &self,
        plan: &PlanCacheStats,
        artifacts: &ArtifactCacheStats,
        profile: &ProfileSnapshot,
    ) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("# HELP bp_server_requests_total Requests served per route.\n");
        out.push_str("# TYPE bp_server_requests_total counter\n");
        for (index, label) in Self::labels() {
            let m = &self.routes[index];
            writeln!(
                out,
                "bp_server_requests_total{{route=\"{label}\"}} {}",
                m.requests.load(Ordering::Relaxed)
            )
            .unwrap();
        }
        out.push_str("# HELP bp_server_responses_total Responses per route and status class.\n");
        out.push_str("# TYPE bp_server_responses_total counter\n");
        for (index, label) in Self::labels() {
            let m = &self.routes[index];
            for (i, class) in ["2xx", "4xx", "5xx"].iter().enumerate() {
                writeln!(
                    out,
                    "bp_server_responses_total{{route=\"{label}\",class=\"{class}\"}} {}",
                    m.classes[i].load(Ordering::Relaxed)
                )
                .unwrap();
            }
        }
        out.push_str(
            "# HELP bp_server_request_duration_us Request latency histogram, microseconds.\n",
        );
        out.push_str("# TYPE bp_server_request_duration_us histogram\n");
        for (index, label) in Self::labels() {
            let m = &self.routes[index];
            let mut cumulative = 0u64;
            for (i, le) in LATENCY_BUCKETS_US.iter().enumerate() {
                cumulative += m.buckets[i].load(Ordering::Relaxed);
                writeln!(
                    out,
                    "bp_server_request_duration_us_bucket{{route=\"{label}\",le=\"{le}\"}} {cumulative}",
                )
                .unwrap();
            }
            cumulative += m.buckets[LATENCY_BUCKETS_US.len()].load(Ordering::Relaxed);
            writeln!(
                out,
                "bp_server_request_duration_us_bucket{{route=\"{label}\",le=\"+Inf\"}} {cumulative}",
            )
            .unwrap();
            writeln!(
                out,
                "bp_server_request_duration_us_sum{{route=\"{label}\"}} {}",
                m.sum_us.load(Ordering::Relaxed)
            )
            .unwrap();
            writeln!(
                out,
                "bp_server_request_duration_us_count{{route=\"{label}\"}} {}",
                m.requests.load(Ordering::Relaxed)
            )
            .unwrap();
        }
        // Request-scoped phase spans, one histogram per phase in fixed
        // label order — every series renders unconditionally, so two
        // scrapes always agree on series order.
        out.push_str(
            "# HELP bp_server_phase_duration_us Request phase span durations \
             (parse/dispatch/write), microseconds.\n",
        );
        out.push_str("# TYPE bp_server_phase_duration_us histogram\n");
        for phase in ServerPhase::ALL {
            let m = &self.phases[phase.index()];
            let label = phase.label();
            let mut cumulative = 0u64;
            for (i, le) in LATENCY_BUCKETS_US.iter().enumerate() {
                cumulative += m.buckets[i].load(Ordering::Relaxed);
                writeln!(
                    out,
                    "bp_server_phase_duration_us_bucket{{phase=\"{label}\",le=\"{le}\"}} {cumulative}",
                )
                .unwrap();
            }
            cumulative += m.buckets[LATENCY_BUCKETS_US.len()].load(Ordering::Relaxed);
            writeln!(
                out,
                "bp_server_phase_duration_us_bucket{{phase=\"{label}\",le=\"+Inf\"}} {cumulative}",
            )
            .unwrap();
            writeln!(
                out,
                "bp_server_phase_duration_us_sum{{phase=\"{label}\"}} {}",
                m.sum_us.load(Ordering::Relaxed)
            )
            .unwrap();
            writeln!(
                out,
                "bp_server_phase_duration_us_count{{phase=\"{label}\"}} {}",
                m.count.load(Ordering::Relaxed)
            )
            .unwrap();
        }
        // Event-loop serving series. `open_connections` is derived from
        // two monotone counters so the hot path never needs a CAS loop
        // (a racy read can transiently undercount, never go negative
        // thanks to the saturating subtraction).
        let opened = self.opened.load(Ordering::Relaxed);
        let closed = self.closed.load(Ordering::Relaxed);
        out.push_str("# HELP bp_server_open_connections Connections currently admitted.\n");
        out.push_str("# TYPE bp_server_open_connections gauge\n");
        writeln!(out, "bp_server_open_connections {}", opened.saturating_sub(closed)).unwrap();
        let loop_counters = [
            (
                "bp_server_connections_total",
                "TCP connections accepted (admitted or shed).",
                self.accepted.load(Ordering::Relaxed),
            ),
            (
                "bp_server_shed_total",
                "Requests or connections answered 429 by overload shedding.",
                self.shed.load(Ordering::Relaxed),
            ),
            (
                "bp_server_read_stalls_total",
                "Reads that progressed without completing a request.",
                self.read_stalls.load(Ordering::Relaxed),
            ),
            (
                "bp_server_write_stalls_total",
                "Writes that progressed without finishing a response.",
                self.write_stalls.load(Ordering::Relaxed),
            ),
            (
                "bp_server_deadline_closes_total",
                "Connections closed by a read or write deadline.",
                self.deadline_closes.load(Ordering::Relaxed),
            ),
        ];
        for (name, help, value) in loop_counters {
            writeln!(out, "# HELP {name} {help}").unwrap();
            writeln!(out, "# TYPE {name} counter").unwrap();
            writeln!(out, "{name} {value}").unwrap();
        }
        // One HELP/TYPE pair per metric family (hits/misses are
        // counters, entry counts are gauges) so strict parsers accept
        // the exposition.
        let counters = [
            ("bp_plan_cache_hits_total", "Plan-cache lookups served from the table.", plan.hits),
            ("bp_plan_cache_misses_total", "Plan-cache lookups that built a plan.", plan.misses),
            (
                "bp_artifact_cache_hits_total",
                "Rendered-response cache lookups served from the table.",
                artifacts.hits,
            ),
            (
                "bp_artifact_cache_misses_total",
                "Rendered-response cache lookups that found nothing.",
                artifacts.misses,
            ),
            (
                "bp_artifact_cache_evictions_total",
                "Rendered responses evicted to admit fresh requests (second chance).",
                artifacts.evictions,
            ),
        ];
        for (name, help, value) in counters {
            writeln!(out, "# HELP {name} {help}").unwrap();
            writeln!(out, "# TYPE {name} counter").unwrap();
            writeln!(out, "{name} {value}").unwrap();
        }
        let gauges = [
            ("bp_plan_cache_entries", "Distinct plans memoized.", plan.entries),
            ("bp_artifact_cache_entries", "Distinct rendered responses memoized.", artifacts.entries),
        ];
        for (name, help, value) in gauges {
            writeln!(out, "# HELP {name} {help}").unwrap();
            writeln!(out, "# TYPE {name} gauge").unwrap();
            writeln!(out, "{name} {value}").unwrap();
        }
        // Deterministic per-strategy cold-build counters (virtual-time
        // side: same request sequence → same counts on any fleet width).
        out.push_str("# HELP bp_plan_builds_total Cold plan builds per lowering strategy.\n");
        out.push_str("# TYPE bp_plan_builds_total counter\n");
        for (i, strat) in LoweringStrategy::STRATEGIES.iter().enumerate() {
            writeln!(out, "bp_plan_builds_total{{strategy=\"{}\"}} {}", strat.name(), plan.builds[i])
                .unwrap();
        }
        // Host-profiler histograms (wall-clock side). Bucket labels are
        // the profiler's log-scale nanosecond bounds expressed in
        // seconds; every series renders even when empty.
        const SECOND_LABELS: [&str; 7] =
            ["0.000001", "0.00001", "0.0001", "0.001", "0.01", "0.1", "1"];
        let build = profile.phase(Phase::PlanBuild);
        out.push_str("# HELP bp_plan_build_seconds Cold plan-build wall time, seconds.\n");
        out.push_str("# TYPE bp_plan_build_seconds histogram\n");
        let mut cumulative = 0u64;
        for (i, le) in SECOND_LABELS.iter().enumerate() {
            cumulative += build.buckets[i];
            writeln!(out, "bp_plan_build_seconds_bucket{{le=\"{le}\"}} {cumulative}").unwrap();
        }
        cumulative += build.buckets[BUCKETS - 1];
        writeln!(out, "bp_plan_build_seconds_bucket{{le=\"+Inf\"}} {cumulative}").unwrap();
        writeln!(out, "bp_plan_build_seconds_sum {:.9}", build.total_ns as f64 / 1e9).unwrap();
        writeln!(out, "bp_plan_build_seconds_count {}", build.calls).unwrap();
        // DSE evaluation throughput as a rate histogram, derived from
        // the duration buckets by inversion: an evaluation that took d
        // ns ran at 1e9/d points/sec, so rate <= R means d >= 1e9/R and
        // rate_bucket(le=R) = count - cum_duration(le = 1e9/R). The
        // bounds are exact powers of ten, the inverses of NS_BUCKETS;
        // an evaluation landing exactly on a bound counts in the next
        // faster bucket, which is immaterial for telemetry.
        let dse = profile.phase(Phase::DseEvaluate);
        let mut cum_dur = [0u64; NS_BUCKETS.len()];
        let mut acc = 0u64;
        for i in 0..NS_BUCKETS.len() {
            acc += dse.buckets[i];
            cum_dur[i] = acc;
        }
        out.push_str(
            "# HELP bp_dse_points_per_second DSE candidate evaluation throughput, points/sec.\n",
        );
        out.push_str("# TYPE bp_dse_points_per_second histogram\n");
        const RATE_BOUNDS: [&str; 7] = ["1", "10", "100", "1000", "10000", "100000", "1000000"];
        for (j, le) in RATE_BOUNDS.iter().enumerate() {
            let count = dse.calls.saturating_sub(cum_dur[NS_BUCKETS.len() - 1 - j]);
            writeln!(out, "bp_dse_points_per_second_bucket{{le=\"{le}\"}} {count}").unwrap();
        }
        writeln!(out, "bp_dse_points_per_second_bucket{{le=\"+Inf\"}} {}", dse.calls).unwrap();
        // sum/count are chosen so avg = sum/count equals the aggregate
        // throughput calls/(total wall time) — the rate the bench gate
        // tracks — rather than an untracked per-observation sum.
        let rate_sum =
            if dse.total_ns == 0 { 0.0 } else { dse.calls as f64 * dse.per_sec() };
        writeln!(out, "bp_dse_points_per_second_sum {rate_sum:.3}").unwrap();
        writeln!(out, "bp_dse_points_per_second_count {}", dse.calls).unwrap();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_land_in_the_right_series() {
        let m = ServerMetrics::new();
        m.record(Some(Route::Query), 200, 80);
        m.record(Some(Route::Query), 200, 700);
        m.record(Some(Route::Query), 400, 2_000_000);
        m.record(Some(Route::Healthz), 200, 10);
        m.record(None, 404, 5);
        assert_eq!(m.requests_total(), 5);
        let text = m.render(&PlanCacheStats::default(), &ArtifactCacheStats::default(), &ProfileSnapshot::default());
        assert!(text.contains("bp_server_requests_total{route=\"query\"} 3"), "{text}");
        assert!(text.contains("bp_server_requests_total{route=\"healthz\"} 1"));
        // Unrouted traffic (404s, framing errors) is visible too.
        assert!(text.contains("bp_server_requests_total{route=\"other\"} 1"), "{text}");
        assert!(text.contains("bp_server_responses_total{route=\"other\",class=\"4xx\"} 1"));
        assert!(text.contains("bp_server_responses_total{route=\"query\",class=\"2xx\"} 2"));
        assert!(text.contains("bp_server_responses_total{route=\"query\",class=\"4xx\"} 1"));
        // Histogram: 80us falls in le=100, 700us in le=1000 (cumulative 2),
        // 2s only in +Inf (cumulative 3).
        assert!(text.contains("bp_server_request_duration_us_bucket{route=\"query\",le=\"100\"} 1"));
        assert!(
            text.contains("bp_server_request_duration_us_bucket{route=\"query\",le=\"1000\"} 2")
        );
        assert!(
            text.contains("bp_server_request_duration_us_bucket{route=\"query\",le=\"+Inf\"} 3")
        );
        assert!(text.contains("bp_server_request_duration_us_count{route=\"query\"} 3"));
    }

    #[test]
    fn renders_event_loop_series() {
        let m = ServerMetrics::new();
        for _ in 0..3 {
            m.conn_accepted();
        }
        m.conn_opened();
        m.conn_opened();
        m.conn_closed();
        m.record_shed();
        m.record_read_stall();
        m.record_read_stall();
        m.record_write_stall();
        m.record_deadline_close();
        assert_eq!(m.shed_total(), 1);
        let text = m.render(&PlanCacheStats::default(), &ArtifactCacheStats::default(), &ProfileSnapshot::default());
        assert!(text.contains("bp_server_open_connections 1"), "{text}");
        assert!(text.contains("bp_server_connections_total 3"), "{text}");
        assert!(text.contains("bp_server_shed_total 1"), "{text}");
        assert!(text.contains("bp_server_read_stalls_total 2"), "{text}");
        assert!(text.contains("bp_server_write_stalls_total 1"), "{text}");
        assert!(text.contains("bp_server_deadline_closes_total 1"), "{text}");
        // The gauge never goes negative even if closes race ahead.
        m.conn_closed();
        m.conn_closed();
        let text = m.render(&PlanCacheStats::default(), &ArtifactCacheStats::default(), &ProfileSnapshot::default());
        assert!(text.contains("bp_server_open_connections 0"), "{text}");
    }

    #[test]
    fn renders_cache_counters() {
        let m = ServerMetrics::new();
        let plan =
            PlanCacheStats { hits: 7, misses: 3, entries: 3, builds: [4, 9, 1, 0] };
        let art = ArtifactCacheStats { hits: 2, misses: 1, entries: 1, evictions: 5 };
        let text = m.render(&plan, &art, &ProfileSnapshot::default());
        assert!(text.contains("bp_plan_cache_hits_total 7"));
        assert!(text.contains("bp_plan_cache_misses_total 3"));
        assert!(text.contains("bp_plan_cache_entries 3"));
        assert!(text.contains("bp_artifact_cache_hits_total 2"));
        assert!(text.contains("bp_artifact_cache_misses_total 1"));
        assert!(text.contains("bp_artifact_cache_evictions_total 5"));
        assert!(text.contains("bp_artifact_cache_entries 1"));
        // Per-strategy cold-build counters, fixed label order.
        assert!(text.contains("bp_plan_builds_total{strategy=\"trad\"} 4"), "{text}");
        assert!(text.contains("bp_plan_builds_total{strategy=\"bp\"} 9"));
        assert!(text.contains("bp_plan_builds_total{strategy=\"eco-os\"} 1"));
        assert!(text.contains("bp_plan_builds_total{strategy=\"eco-is\"} 0"));
    }

    #[test]
    fn renders_phase_span_histograms() {
        let m = ServerMetrics::new();
        m.record_phase(ServerPhase::Parse, 80);
        m.record_phase(ServerPhase::Dispatch, 700);
        m.record_phase(ServerPhase::Dispatch, 2_000_000);
        m.record_phase(ServerPhase::Write, 40);
        let text = m.render(
            &PlanCacheStats::default(),
            &ArtifactCacheStats::default(),
            &ProfileSnapshot::default(),
        );
        assert!(
            text.contains("bp_server_phase_duration_us_bucket{phase=\"parse\",le=\"100\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("bp_server_phase_duration_us_bucket{phase=\"dispatch\",le=\"1000\"} 1")
        );
        assert!(
            text.contains("bp_server_phase_duration_us_bucket{phase=\"dispatch\",le=\"+Inf\"} 2")
        );
        assert!(text.contains("bp_server_phase_duration_us_count{phase=\"dispatch\"} 2"));
        assert!(text.contains("bp_server_phase_duration_us_sum{phase=\"write\"} 40"));
        // Empty phases still render every series — scrape-stable order.
        assert!(text.contains("bp_server_phase_duration_us_count{phase=\"write\"} 1"));
    }

    #[test]
    fn renders_profiler_histograms() {
        use crate::trace::profile::PhaseStats;
        let m = ServerMetrics::new();
        let mut profile = ProfileSnapshot::default();
        // Three builds: 5us, 50us, 2s (overflow).
        let mut build = PhaseStats { calls: 3, total_ns: 2_000_055_000, buckets: [0; BUCKETS] };
        build.buckets[1] = 1; // le=10us
        build.buckets[2] = 1; // le=100us
        build.buckets[BUCKETS - 1] = 1; // +Inf
        profile.phases[3] = build; // Phase::PlanBuild slot
        // Four DSE evaluations: two in the le=10us duration bucket
        // (rate class >1e5 pts/s), one in le=1ms (rate class >1e3),
        // one at ~2s (sub-1 pts/s, overflow bucket).
        let mut dse = PhaseStats { calls: 4, total_ns: 2_001_020_000, buckets: [0; BUCKETS] };
        dse.buckets[1] = 2;
        dse.buckets[3] = 1;
        dse.buckets[BUCKETS - 1] = 1;
        profile.phases[5] = dse; // Phase::DseEvaluate slot
        let text =
            m.render(&PlanCacheStats::default(), &ArtifactCacheStats::default(), &profile);
        assert!(text.contains("bp_plan_build_seconds_bucket{le=\"0.00001\"} 1"), "{text}");
        assert!(text.contains("bp_plan_build_seconds_bucket{le=\"0.0001\"} 2"));
        assert!(text.contains("bp_plan_build_seconds_bucket{le=\"1\"} 2"));
        assert!(text.contains("bp_plan_build_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("bp_plan_build_seconds_count 3"));
        assert!(text.contains("bp_plan_build_seconds_sum 2.000055000"));
        // Rate inversion: the 2s evaluation runs below 1 pt/s (le="1");
        // the le=1ms duration bucket inverts to faster-than-1e3, so it
        // first appears at le="10000"; the le=10us pair inverts to
        // faster-than-1e5 and first appears at le="1000000".
        assert!(text.contains("bp_dse_points_per_second_bucket{le=\"1\"} 1"), "{text}");
        assert!(text.contains("bp_dse_points_per_second_bucket{le=\"1000\"} 1"));
        assert!(text.contains("bp_dse_points_per_second_bucket{le=\"10000\"} 2"));
        assert!(text.contains("bp_dse_points_per_second_bucket{le=\"100000\"} 2"));
        assert!(text.contains("bp_dse_points_per_second_bucket{le=\"1000000\"} 4"));
        assert!(text.contains("bp_dse_points_per_second_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("bp_dse_points_per_second_count 4"));
        // Buckets are cumulative (monotone) across the whole family.
        let counts: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("bp_dse_points_per_second_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
    }
}
