//! **Algorithm 2** — BP-im2col of dilated mode.
//!
//! During gradient calculation the dynamic matrix *A* is the
//! zero-inserted loss map (`[B,N,Ho'',Wo'']`) acting as the convolving
//! kernel. It needs no im2col (each row is just one output channel's
//! flattened map) and has only zero-insertions, detected by the
//! generalized Eq. (4) with per-axis strides `(Sh, Sw)`. Kernel dilation
//! does not appear here — it only shifts the *stationary* matrix's taps.
//!
//! Grouped layers run one virtual matrix per channel group `g`
//! (`N/G` rows); `G == 1, g == 0` is the paper's geometry.

use crate::conv::ConvParams;
use crate::im2col::Zone;
use crate::tensor::{Matrix, Tensor4};

/// A decoded pixel of the virtual dynamic matrix A.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VirtualPixelA {
    /// Output-channel index *within the group* (the matrix row).
    pub n: usize,
    /// Batch index.
    pub b: usize,
    /// Row inside the virtual zero-inserted `Ho'' x Wo''` channel.
    pub h: usize,
    /// Column inside the virtual zero-inserted channel.
    pub w: usize,
}

/// Lines 1–3 of Algorithm 2: decompose a flat virtual-matrix address.
#[inline]
pub fn decompose(addr_in: usize, p: &ConvParams) -> VirtualPixelA {
    let (h2, w2) = (p.ho2(), p.wo2());
    let cols = p.b * h2 * w2;
    let (n, col) = (addr_in / cols, addr_in % cols);
    let (temp, w) = (col / w2, col % w2);
    let (b, h) = (temp / h2, temp % h2);
    VirtualPixelA { n, b, h, w }
}

/// NZ detection of dilated mode, generalized Eq. (4): a pixel is a
/// structural zero iff its axis stride does not divide its position. No
/// bounds check is needed: `h < Ho'' = (Ho-1)Sh+1` implies
/// `h/Sh <= Ho-1`.
#[inline]
pub fn nz_detect(h: usize, w: usize, p: &ConvParams) -> Zone {
    if h % p.sh > 0 || w % p.sw > 0 {
        Zone::Area1
    } else {
        Zone::NonZero
    }
}

/// Full Algorithm 2: map an address of group `g`'s virtual matrix A to
/// the address in the compact loss map, or `None` for zero-insertions.
#[inline]
pub fn map_addr(addr_in: usize, p: &ConvParams, g: usize) -> Option<usize> {
    let px = decompose(addr_in, p);
    if nz_detect(px.h, px.w, p).is_zero() {
        return None; // addr_out = NULL — zero-insertions
    }
    let (ho, wo) = (p.ho(), p.wo());
    let n_abs = g * p.ng() + px.n;
    Some(px.b * p.n * ho * wo + n_abs * ho * wo + (px.h / p.sh) * wo + px.w / p.sw)
}

/// Number of addresses in one group's virtual matrix A
/// (`(N/G) x (B*Ho''*Wo'')`).
pub const fn virtual_len(p: &ConvParams) -> usize {
    p.ng() * p.b * p.ho2() * p.wo2()
}

/// Streaming address generator for the dilated mode: carries `(n, b, h,
/// w)` as counters (hardware incrementers) instead of dividing per
/// address. Equivalent to [`map_addr`] over `0..virtual_len` (tested).
pub struct AddrGen<'a> {
    p: &'a ConvParams,
    /// Absolute output-channel index (`g*N/G + n`).
    n_abs: usize,
    /// Rows emitted so far (terminates after `N/G`).
    row: usize,
    b: usize,
    h: usize,
    w: usize,
}

impl<'a> AddrGen<'a> {
    /// Streaming generator over group `g`'s virtual dynamic matrix.
    pub fn new(p: &'a ConvParams, g: usize) -> Self {
        assert!(g < p.groups);
        Self { p, n_abs: g * p.ng(), row: 0, b: 0, h: 0, w: 0 }
    }
}

impl Iterator for AddrGen<'_> {
    /// `Some(None)` = zero-insertion; `Some(Some(a))` = compact address.
    type Item = Option<usize>;

    #[inline]
    fn next(&mut self) -> Option<Option<usize>> {
        let p = self.p;
        if self.row == p.ng() {
            return None;
        }
        let out = if self.h % p.sh == 0 && self.w % p.sw == 0 {
            let (ho, wo) = (p.ho(), p.wo());
            Some(
                self.b * p.n * ho * wo
                    + self.n_abs * ho * wo
                    + self.h / p.sh * wo
                    + self.w / p.sw,
            )
        } else {
            None
        };
        self.w += 1;
        if self.w == p.wo2() {
            self.w = 0;
            self.h += 1;
            if self.h == p.ho2() {
                self.h = 0;
                self.b += 1;
                if self.b == p.b {
                    self.b = 0;
                    self.row += 1;
                    self.n_abs += 1;
                }
            }
        }
        Some(out)
    }
}

/// Materialize group `g`'s lowered matrix A through the implicit mapping
/// (what the hardware's dynamic address-generation module + crossbar
/// produce). Must equal [`crate::im2col::traditional::lower_grad_a`]
/// over the explicitly dilated map.
pub fn gather_matrix(dy: &Tensor4, p: &ConvParams, g: usize) -> Matrix {
    assert_eq!(dy.dims, [p.b, p.n, p.ho(), p.wo()]);
    let mut m = Matrix::zeros(p.ng(), p.b * p.ho2() * p.wo2());
    for (out, mapped) in m.data.iter_mut().zip(AddrGen::new(p, g)) {
        if let Some(addr_out) = mapped {
            *out = dy.data[addr_out];
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::{reorg, traditional};
    use crate::tensor::Rng;

    fn check_gather_equals_explicit(p: ConvParams, seed: u64) {
        let mut rng = Rng::new(seed);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let dyd = reorg::dilate_loss(&dy, &p);
        for g in 0..p.groups {
            let implicit = gather_matrix(&dy, &p, g);
            let explicit = traditional::lower_grad_a(&dyd, &p, g);
            assert_eq!(implicit, explicit, "Algorithm 2 mismatch for {p:?} group {g}");
        }
    }

    #[test]
    fn alg2_equals_explicit_stride2() {
        check_gather_equals_explicit(ConvParams::basic(2, 2, 9, 9, 3, 3, 3, 2, 1, 1), 30);
    }

    #[test]
    fn alg2_equals_explicit_stride3() {
        check_gather_equals_explicit(ConvParams::basic(1, 1, 13, 10, 2, 3, 2, 3, 1, 0), 31);
    }

    #[test]
    fn alg2_equals_explicit_stride1_dense() {
        check_gather_equals_explicit(ConvParams::basic(1, 1, 6, 6, 2, 3, 3, 1, 1, 1), 32);
    }

    #[test]
    fn alg2_equals_explicit_asymmetric_stride() {
        check_gather_equals_explicit(
            ConvParams::basic(1, 1, 9, 12, 2, 3, 3, 1, 1, 1).with_stride(2, 3),
            33,
        );
        check_gather_equals_explicit(
            ConvParams::basic(2, 1, 12, 9, 2, 3, 3, 1, 1, 1).with_stride(3, 2),
            34,
        );
    }

    #[test]
    fn alg2_equals_explicit_grouped() {
        check_gather_equals_explicit(ConvParams::basic(1, 4, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(2), 35);
        check_gather_equals_explicit(ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(4), 36);
    }

    #[test]
    fn alg2_equals_explicit_dilated_kernel_is_transparent() {
        // Kernel dilation must not change matrix A (only the stationary
        // operand samples dilated taps).
        let base = ConvParams::basic(1, 1, 11, 11, 2, 3, 3, 2, 2, 2);
        let dil = base.with_dilation(2, 2);
        let mut rng = Rng::new(37);
        // Same Ho/Wo? Not necessarily; build dY per geometry.
        let dy_b = Tensor4::random([base.b, base.n, base.ho(), base.wo()], &mut rng);
        let dy_d = Tensor4::random([dil.b, dil.n, dil.ho(), dil.wo()], &mut rng);
        check_gather_equals_explicit(dil, 38);
        assert_eq!(gather_matrix(&dy_b, &base, 0).rows, base.ng());
        assert_eq!(gather_matrix(&dy_d, &dil, 0).cols, dil.b * dil.ho2() * dil.wo2());
    }

    #[test]
    fn nz_detection_eq4() {
        let p = ConvParams::basic(1, 1, 8, 8, 1, 2, 2, 2, 0, 0);
        assert_eq!(nz_detect(0, 0, &p), Zone::NonZero);
        assert_eq!(nz_detect(1, 0, &p), Zone::Area1);
        assert_eq!(nz_detect(0, 3, &p), Zone::Area1);
        assert_eq!(nz_detect(2, 4, &p), Zone::NonZero);
    }

    #[test]
    fn nz_detection_eq4_asymmetric() {
        let p = ConvParams::basic(1, 1, 12, 12, 1, 3, 3, 1, 1, 1).with_stride(2, 3);
        assert_eq!(nz_detect(2, 3, &p), Zone::NonZero);
        assert_eq!(nz_detect(2, 2, &p), Zone::Area1); // 2 % Sw=3
        assert_eq!(nz_detect(1, 3, &p), Zone::Area1); // 1 % Sh=2
    }

    #[test]
    fn addrgen_stream_equals_map_addr() {
        for p in [
            ConvParams::basic(2, 1, 9, 9, 2, 3, 3, 2, 1, 1),
            ConvParams::basic(1, 1, 10, 7, 3, 3, 2, 3, 1, 0),
            ConvParams::basic(1, 1, 9, 12, 2, 3, 3, 1, 1, 1).with_stride(2, 3),
        ] {
            let stream: Vec<Option<usize>> = AddrGen::new(&p, 0).collect();
            assert_eq!(stream.len(), virtual_len(&p));
            for (addr, got) in stream.into_iter().enumerate() {
                assert_eq!(got, map_addr(addr, &p, 0), "{p:?} addr {addr}");
            }
        }
    }

    #[test]
    fn addrgen_stream_equals_map_addr_grouped() {
        let p = ConvParams::basic(1, 6, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(3);
        for g in 0..p.groups {
            let stream: Vec<Option<usize>> = AddrGen::new(&p, g).collect();
            assert_eq!(stream.len(), virtual_len(&p));
            for (addr, got) in stream.into_iter().enumerate() {
                assert_eq!(got, map_addr(addr, &p, g), "group {g} addr {addr}");
            }
        }
    }

    #[test]
    fn sparsity_is_exactly_one_minus_ho_wo_ratio() {
        // Eq. (4) zeros: 1 - (Ho*Wo)/(Ho''*Wo'').
        let p = ConvParams::basic(1, 1, 17, 17, 2, 3, 3, 2, 1, 1);
        let nz = (0..virtual_len(&p)).filter(|a| map_addr(*a, &p, 0).is_some()).count();
        assert_eq!(nz, p.b * p.n * p.ho() * p.wo());
    }

    #[test]
    fn every_compact_address_hit_exactly_once_per_row() {
        let p = ConvParams::basic(1, 1, 9, 9, 2, 3, 3, 2, 1, 1);
        let mut counts = vec![0usize; p.output_elems()];
        for a in 0..virtual_len(&p) {
            if let Some(o) = map_addr(a, &p, 0) {
                counts[o] += 1;
            }
        }
        // Matrix A is a permutation-with-zeros of the compact map: each
        // compact element appears exactly once.
        assert!(counts.iter().all(|c| *c == 1), "counts {counts:?}");
    }

    #[test]
    fn grouped_matrices_tile_the_compact_map() {
        // Across all groups, every compact element appears exactly once.
        let p = ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(2);
        let mut counts = vec![0usize; p.output_elems()];
        for g in 0..p.groups {
            for a in 0..virtual_len(&p) {
                if let Some(o) = map_addr(a, &p, g) {
                    counts[o] += 1;
                }
            }
        }
        assert!(counts.iter().all(|c| *c == 1), "counts {counts:?}");
    }
}
