//! **Algorithm 2** — BP-im2col of dilated mode.
//!
//! During gradient calculation the dynamic matrix *A* is the
//! zero-inserted loss map (`[B,N,Ho'',Wo'']`) acting as the convolving
//! kernel. It needs no im2col (each row is just one output channel's
//! flattened map) and has only zero-insertions, detected by Eq. (4).

use crate::conv::ConvParams;
use crate::im2col::Zone;
use crate::tensor::{Matrix, Tensor4};

/// A decoded pixel of the virtual dynamic matrix A.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VirtualPixelA {
    /// Output-channel index (the matrix row).
    pub n: usize,
    /// Batch index.
    pub b: usize,
    /// Position inside the virtual zero-inserted `Ho'' x Wo''` channel.
    pub h: usize,
    pub w: usize,
}

/// Lines 1–3 of Algorithm 2: decompose a flat virtual-matrix address.
#[inline]
pub fn decompose(addr_in: usize, p: &ConvParams) -> VirtualPixelA {
    let (h2, w2) = (p.ho2(), p.wo2());
    let cols = p.b * h2 * w2;
    let (n, col) = (addr_in / cols, addr_in % cols);
    let (temp, w) = (col / w2, col % w2);
    let (b, h) = (temp / h2, temp % h2);
    VirtualPixelA { n, b, h, w }
}

/// NZ detection of dilated mode, Eq. (4): a pixel is a structural zero
/// iff the stride does not divide its position. No bounds check is
/// needed: `h < Ho'' = (Ho-1)S+1` implies `h/S <= Ho-1`.
#[inline]
pub fn nz_detect(h: usize, w: usize, p: &ConvParams) -> Zone {
    if h % p.s > 0 || w % p.s > 0 {
        Zone::Area1
    } else {
        Zone::NonZero
    }
}

/// Full Algorithm 2: map an address of the virtual matrix A to the
/// address in the compact loss map, or `None` for zero-insertions.
#[inline]
pub fn map_addr(addr_in: usize, p: &ConvParams) -> Option<usize> {
    let px = decompose(addr_in, p);
    if nz_detect(px.h, px.w, p).is_zero() {
        return None; // addr_out = NULL — zero-insertions
    }
    let (ho, wo) = (p.ho(), p.wo());
    Some(px.b * p.n * ho * wo + px.n * ho * wo + (px.h / p.s) * wo + px.w / p.s)
}

/// Number of addresses in the virtual matrix A (`N x (B*Ho''*Wo'')`).
pub const fn virtual_len(p: &ConvParams) -> usize {
    p.n * p.b * p.ho2() * p.wo2()
}

/// Streaming address generator for the dilated mode: carries `(n, b, h,
/// w)` as counters (hardware incrementers) instead of dividing per
/// address. Equivalent to [`map_addr`] over `0..virtual_len` (tested).
pub struct AddrGen<'a> {
    p: &'a ConvParams,
    n: usize,
    b: usize,
    h: usize,
    w: usize,
}

impl<'a> AddrGen<'a> {
    pub fn new(p: &'a ConvParams) -> Self {
        Self { p, n: 0, b: 0, h: 0, w: 0 }
    }
}

impl Iterator for AddrGen<'_> {
    /// `Some(None)` = zero-insertion; `Some(Some(a))` = compact address.
    type Item = Option<usize>;

    #[inline]
    fn next(&mut self) -> Option<Option<usize>> {
        let p = self.p;
        if self.n == p.n {
            return None;
        }
        let out = if self.h % p.s == 0 && self.w % p.s == 0 {
            let (ho, wo) = (p.ho(), p.wo());
            Some(self.b * p.n * ho * wo + self.n * ho * wo + self.h / p.s * wo + self.w / p.s)
        } else {
            None
        };
        self.w += 1;
        if self.w == p.wo2() {
            self.w = 0;
            self.h += 1;
            if self.h == p.ho2() {
                self.h = 0;
                self.b += 1;
                if self.b == p.b {
                    self.b = 0;
                    self.n += 1;
                }
            }
        }
        Some(out)
    }
}

/// Materialize the lowered matrix A through the implicit mapping (what
/// the hardware's dynamic address-generation module + crossbar produce).
/// Must equal [`crate::im2col::traditional::lower_grad_a`] over the
/// explicitly dilated map.
pub fn gather_matrix(dy: &Tensor4, p: &ConvParams) -> Matrix {
    assert_eq!(dy.dims, [p.b, p.n, p.ho(), p.wo()]);
    let mut m = Matrix::zeros(p.n, p.b * p.ho2() * p.wo2());
    for (out, mapped) in m.data.iter_mut().zip(AddrGen::new(p)) {
        if let Some(addr_out) = mapped {
            *out = dy.data[addr_out];
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::{reorg, traditional};
    use crate::tensor::Rng;

    fn check_gather_equals_explicit(p: ConvParams, seed: u64) {
        let mut rng = Rng::new(seed);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let implicit = gather_matrix(&dy, &p);
        let explicit = traditional::lower_grad_a(&reorg::dilate_loss(&dy, &p), &p);
        assert_eq!(implicit, explicit, "Algorithm 2 mismatch for {p:?}");
    }

    #[test]
    fn alg2_equals_explicit_stride2() {
        check_gather_equals_explicit(
            ConvParams { b: 2, c: 2, hi: 9, wi: 9, n: 3, kh: 3, kw: 3, s: 2, ph: 1, pw: 1 },
            30,
        );
    }

    #[test]
    fn alg2_equals_explicit_stride3() {
        check_gather_equals_explicit(
            ConvParams { b: 1, c: 1, hi: 13, wi: 10, n: 2, kh: 3, kw: 2, s: 3, ph: 1, pw: 0 },
            31,
        );
    }

    #[test]
    fn alg2_equals_explicit_stride1_dense() {
        check_gather_equals_explicit(
            ConvParams { b: 1, c: 1, hi: 6, wi: 6, n: 2, kh: 3, kw: 3, s: 1, ph: 1, pw: 1 },
            32,
        );
    }

    #[test]
    fn nz_detection_eq4() {
        let p = ConvParams { b: 1, c: 1, hi: 8, wi: 8, n: 1, kh: 2, kw: 2, s: 2, ph: 0, pw: 0 };
        assert_eq!(nz_detect(0, 0, &p), Zone::NonZero);
        assert_eq!(nz_detect(1, 0, &p), Zone::Area1);
        assert_eq!(nz_detect(0, 3, &p), Zone::Area1);
        assert_eq!(nz_detect(2, 4, &p), Zone::NonZero);
    }

    #[test]
    fn addrgen_stream_equals_map_addr() {
        for p in [
            ConvParams { b: 2, c: 1, hi: 9, wi: 9, n: 2, kh: 3, kw: 3, s: 2, ph: 1, pw: 1 },
            ConvParams { b: 1, c: 1, hi: 10, wi: 7, n: 3, kh: 3, kw: 2, s: 3, ph: 1, pw: 0 },
        ] {
            let stream: Vec<Option<usize>> = AddrGen::new(&p).collect();
            assert_eq!(stream.len(), virtual_len(&p));
            for (addr, got) in stream.into_iter().enumerate() {
                assert_eq!(got, map_addr(addr, &p), "{p:?} addr {addr}");
            }
        }
    }

    #[test]
    fn sparsity_is_exactly_one_minus_ho_wo_ratio() {
        // Eq. (4) zeros: 1 - (Ho*Wo)/(Ho''*Wo'').
        let p = ConvParams { b: 1, c: 1, hi: 17, wi: 17, n: 2, kh: 3, kw: 3, s: 2, ph: 1, pw: 1 };
        let nz = (0..virtual_len(&p)).filter(|a| map_addr(*a, &p).is_some()).count();
        assert_eq!(nz, p.b * p.n * p.ho() * p.wo());
    }

    #[test]
    fn every_compact_address_hit_exactly_once_per_row() {
        let p = ConvParams { b: 1, c: 1, hi: 9, wi: 9, n: 2, kh: 3, kw: 3, s: 2, ph: 1, pw: 1 };
        let mut counts = vec![0usize; p.output_elems()];
        for a in 0..virtual_len(&p) {
            if let Some(o) = map_addr(a, &p) {
                counts[o] += 1;
            }
        }
        // Matrix A is a permutation-with-zeros of the compact map: each
        // compact element appears exactly once.
        assert!(counts.iter().all(|c| *c == 1), "counts {counts:?}");
    }
}
