//! End-to-end functional loss / gradient calculation through either
//! lowering path. These are the *functional* pipelines; the cycle-level
//! behaviour of the same dataflow lives in [`crate::accel`].
//!
//! Grouped layers run `G` per-group GEMMs and scatter each result into
//! its channel slice; `G == 1` is exactly the paper's single GEMM.

use crate::conv::ConvParams;
use crate::im2col::{dilated, reorg, traditional, transposed};
use crate::tensor::Tensor4;

/// Which im2col algorithm the accelerator runs.
///
/// **Deprecated alias** of [`crate::accel::strategy::LoweringStrategy`]
/// — the historical two-variant `Mode` grew into the strategy family of
/// DESIGN.md §15, and this re-export keeps every `simulate_pass`
/// caller, bench and example compiling unchanged. `Mode::ALL` is still
/// the paper's two modes ([`LoweringStrategy::ALL`]); the full family
/// is [`LoweringStrategy::STRATEGIES`]. There is exactly one dispatch
/// over it: [`crate::accel::plan::LayerPlan::build`].
pub use crate::accel::strategy::LoweringStrategy as Mode;

/// Which backpropagation pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pass {
    /// Loss calculation (`dX`, transposed-convolution mode).
    Loss,
    /// Gradient calculation (`dW`, dilated-convolution mode).
    Grad,
}

impl Pass {
    /// Both passes, loss first (the order the figures report).
    pub const ALL: [Pass; 2] = [Pass::Loss, Pass::Grad];

    /// Short lowercase name ("loss" / "grad").
    pub fn name(&self) -> &'static str {
        match self {
            Pass::Loss => "loss",
            Pass::Grad => "grad",
        }
    }
}

/// Loss calculation `dX = dYei * Tr(rot180 W)` via the chosen path.
pub fn loss_calc(dy: &Tensor4, w: &Tensor4, p: &ConvParams, mode: Mode) -> Tensor4 {
    // The baseline materializes the zero-spaced map once per layer; every
    // group's stationary matrix is lowered from the same copy.
    // Every implicit strategy (BP and the EcoFlow scatters) computes
    // the same GEMM from the compact tensors — dataflows differ only in
    // cycle cost, never in the math.
    let dyz = match mode {
        Mode::Traditional => Some(reorg::dilate_pad_loss(dy, p)),
        Mode::BpIm2col | Mode::EcoOutputStationary | Mode::EcoInputStationary => None,
    };
    let mut dx = Tensor4::zeros([p.b, p.c, p.hi, p.wi]);
    for g in 0..p.groups {
        let a = traditional::lower_loss_a(w, p, g);
        let b = match &dyz {
            Some(z) => traditional::lower_loss_b(z, p, g),
            None => transposed::gather_matrix(dy, p, g),
        };
        traditional::loss_from_gemm_group(&a.matmul(&b), p, g, &mut dx);
    }
    dx
}

/// Gradient calculation `Tr(dW) = Tr(Xe) * Tr(dYi)` via the chosen path.
pub fn grad_calc(x: &Tensor4, dy: &Tensor4, p: &ConvParams, mode: Mode) -> Tensor4 {
    let dyd = match mode {
        Mode::Traditional => Some(reorg::dilate_loss(dy, p)),
        Mode::BpIm2col | Mode::EcoOutputStationary | Mode::EcoInputStationary => None,
    };
    let xpad = reorg::pad_input(x, p);
    let mut dw = Tensor4::zeros([p.n, p.cg(), p.kh, p.kw]);
    for g in 0..p.groups {
        let a = match &dyd {
            Some(z) => traditional::lower_grad_a(z, p, g),
            None => dilated::gather_matrix(dy, p, g),
        };
        let b = traditional::lower_grad_b(&xpad, p, g);
        traditional::grad_from_gemm_group(&a.matmul(&b), p, g, &mut dw);
    }
    dw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{conv2d_bwd_input, conv2d_bwd_weight};
    use crate::tensor::Rng;

    fn tensors(p: &ConvParams, seed: u64) -> (Tensor4, Tensor4, Tensor4) {
        let mut rng = Rng::new(seed);
        let x = Tensor4::random([p.b, p.c, p.hi, p.wi], &mut rng);
        let w = Tensor4::random([p.n, p.cg(), p.kh, p.kw], &mut rng);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        (x, w, dy)
    }

    fn check_both_modes(p: ConvParams, seed: u64) {
        let (x, w, dy) = tensors(&p, seed);
        let dx_oracle = conv2d_bwd_input(&dy, &w, &p);
        let dw_oracle = conv2d_bwd_weight(&x, &dy, &p);
        for mode in Mode::ALL {
            let dx = loss_calc(&dy, &w, &p, mode);
            let dw = grad_calc(&x, &dy, &p, mode);
            assert!(dx.max_abs_diff(&dx_oracle) < 1e-4, "{mode:?} dX mismatch for {p:?}");
            assert!(dw.max_abs_diff(&dw_oracle) < 1e-3, "{mode:?} dW mismatch for {p:?}");
        }
        // And every strategy agrees bit-for-bit (same GEMM, same
        // operands — the explicit/implicit/scatter split is cycle-level
        // only).
        for s in Mode::STRATEGIES {
            assert_eq!(loss_calc(&dy, &w, &p, s), loss_calc(&dy, &w, &p, Mode::BpIm2col), "{s:?}");
            assert_eq!(grad_calc(&x, &dy, &p, s), grad_calc(&x, &dy, &p, Mode::BpIm2col), "{s:?}");
        }
    }

    #[test]
    fn modes_agree_stride2_pad1() {
        check_both_modes(ConvParams::basic(2, 3, 9, 9, 2, 3, 3, 2, 1, 1), 40);
    }

    #[test]
    fn modes_agree_1x1_stride2() {
        check_both_modes(ConvParams::basic(1, 4, 8, 8, 3, 1, 1, 2, 0, 0), 41);
    }

    #[test]
    fn modes_agree_stride3() {
        check_both_modes(ConvParams::basic(1, 2, 10, 13, 2, 2, 3, 3, 0, 1), 42);
    }

    #[test]
    fn modes_agree_inexact_division() {
        check_both_modes(ConvParams::basic(1, 1, 10, 10, 1, 3, 3, 2, 0, 0), 43);
    }

    #[test]
    fn modes_agree_asymmetric_stride() {
        check_both_modes(ConvParams::basic(1, 2, 9, 12, 2, 3, 3, 1, 1, 1).with_stride(2, 3), 44);
    }

    #[test]
    fn modes_agree_dilated() {
        check_both_modes(ConvParams::basic(1, 2, 11, 11, 2, 3, 3, 1, 2, 2).with_dilation(2, 2), 45);
        check_both_modes(ConvParams::basic(1, 1, 13, 13, 1, 3, 3, 2, 2, 2).with_dilation(2, 2), 46);
    }

    #[test]
    fn modes_agree_grouped() {
        check_both_modes(ConvParams::basic(1, 4, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(2), 47);
        // Depthwise: G == C == N.
        check_both_modes(ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(4), 48);
    }

    #[test]
    fn modes_agree_grouped_dilated_asymmetric() {
        // Everything at once: groups + dilation + asymmetric stride.
        check_both_modes(
            ConvParams::basic(1, 4, 11, 9, 4, 3, 2, 1, 2, 1)
                .with_stride(2, 1)
                .with_dilation(2, 2)
                .with_groups(2),
            49,
        );
    }
}
