//! End-to-end functional loss / gradient calculation through either
//! lowering path. These are the *functional* pipelines; the cycle-level
//! behaviour of the same dataflow lives in [`crate::accel`].

use crate::conv::ConvParams;
use crate::im2col::{dilated, reorg, traditional, transposed};
use crate::tensor::Tensor4;

/// Which im2col algorithm the accelerator runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Traditional im2col: reorganize (materialize zero-spaces), then
    /// dense explicit lowering.
    Traditional,
    /// BP-im2col: implicit lowering straight from the compact tensors.
    BpIm2col,
}

impl Mode {
    /// All modes, in baseline-first order (matches the paper's legends).
    pub const ALL: [Mode; 2] = [Mode::Traditional, Mode::BpIm2col];

    /// The paper's legend name.
    pub fn legend(&self) -> &'static str {
        match self {
            Mode::Traditional => "Original",
            Mode::BpIm2col => "Ours",
        }
    }
}

/// Which backpropagation pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Pass {
    /// Loss calculation (`dX`, transposed-convolution mode).
    Loss,
    /// Gradient calculation (`dW`, dilated-convolution mode).
    Grad,
}

impl Pass {
    pub const ALL: [Pass; 2] = [Pass::Loss, Pass::Grad];

    pub fn name(&self) -> &'static str {
        match self {
            Pass::Loss => "loss",
            Pass::Grad => "grad",
        }
    }
}

/// Loss calculation `dX = dYei * Tr(rot180 W)` via the chosen path.
pub fn loss_calc(dy: &Tensor4, w: &Tensor4, p: &ConvParams, mode: Mode) -> Tensor4 {
    let a = traditional::lower_loss_a(w, p);
    let b = match mode {
        Mode::Traditional => traditional::lower_loss_b(&reorg::dilate_pad_loss(dy, p), p),
        Mode::BpIm2col => transposed::gather_matrix(dy, p),
    };
    traditional::loss_from_gemm(&a.matmul(&b), p)
}

/// Gradient calculation `Tr(dW) = Tr(Xe) * Tr(dYi)` via the chosen path.
pub fn grad_calc(x: &Tensor4, dy: &Tensor4, p: &ConvParams, mode: Mode) -> Tensor4 {
    let a = match mode {
        Mode::Traditional => traditional::lower_grad_a(&reorg::dilate_loss(dy, p), p),
        Mode::BpIm2col => dilated::gather_matrix(dy, p),
    };
    let b = traditional::lower_grad_b(&reorg::pad_input(x, p), p);
    traditional::grad_from_gemm(&a.matmul(&b), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{conv2d_bwd_input, conv2d_bwd_weight};
    use crate::tensor::Rng;

    fn tensors(p: &ConvParams, seed: u64) -> (Tensor4, Tensor4, Tensor4) {
        let mut rng = Rng::new(seed);
        let x = Tensor4::random([p.b, p.c, p.hi, p.wi], &mut rng);
        let w = Tensor4::random([p.n, p.c, p.kh, p.kw], &mut rng);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        (x, w, dy)
    }

    fn check_both_modes(p: ConvParams, seed: u64) {
        let (x, w, dy) = tensors(&p, seed);
        let dx_oracle = conv2d_bwd_input(&dy, &w, &p);
        let dw_oracle = conv2d_bwd_weight(&x, &dy, &p);
        for mode in Mode::ALL {
            let dx = loss_calc(&dy, &w, &p, mode);
            let dw = grad_calc(&x, &dy, &p, mode);
            assert!(dx.max_abs_diff(&dx_oracle) < 1e-4, "{mode:?} dX mismatch for {p:?}");
            assert!(dw.max_abs_diff(&dw_oracle) < 1e-3, "{mode:?} dW mismatch for {p:?}");
        }
        // And the two modes agree bit-for-bit (same GEMM, same operands).
        assert_eq!(
            loss_calc(&dy, &w, &p, Mode::Traditional),
            loss_calc(&dy, &w, &p, Mode::BpIm2col)
        );
        assert_eq!(
            grad_calc(&x, &dy, &p, Mode::Traditional),
            grad_calc(&x, &dy, &p, Mode::BpIm2col)
        );
    }

    #[test]
    fn modes_agree_stride2_pad1() {
        check_both_modes(ConvParams { b: 2, c: 3, hi: 9, wi: 9, n: 2, kh: 3, kw: 3, s: 2, ph: 1, pw: 1 }, 40);
    }

    #[test]
    fn modes_agree_1x1_stride2() {
        check_both_modes(ConvParams { b: 1, c: 4, hi: 8, wi: 8, n: 3, kh: 1, kw: 1, s: 2, ph: 0, pw: 0 }, 41);
    }

    #[test]
    fn modes_agree_stride3() {
        check_both_modes(ConvParams { b: 1, c: 2, hi: 10, wi: 13, n: 2, kh: 2, kw: 3, s: 3, ph: 0, pw: 1 }, 42);
    }

    #[test]
    fn modes_agree_inexact_division() {
        check_both_modes(ConvParams { b: 1, c: 1, hi: 10, wi: 10, n: 1, kh: 3, kw: 3, s: 2, ph: 0, pw: 0 }, 43);
    }
}
