//! Implicit im2col of the *inference* pass — the mode the traditional
//! accelerator was designed around ("state-of-the-art systolic
//! array-based accelerators adopt the traditional im2col algorithm to
//! accelerate the inference of convolutional layers").
//!
//! Inference lowers `Y = X * W` to `G` per-group GEMMs
//! `A_g[N/G x (C/G)*Kh*Kw] . B_g[(C/G)*Kh*Kw x B*Ho*Wo]` where `B_g` is
//! the im2col of the *padded* input's group channels. The only
//! structural zeros are the padding halo, detected with two comparators
//! per axis — this is the 51-cycle stationary pipeline of Table III,
//! shared by both modes. Implemented here so the repo covers the full
//! training step (fwd + loss + grad) and the coordinator can report
//! whole-step costs.

use crate::conv::ConvParams;
use crate::tensor::{Matrix, Tensor4};

/// Virtual matrix B dimensions for one inference group:
/// `((C/G)*Kh*Kw) x (B*Ho*Wo)`.
pub const fn virtual_len(p: &ConvParams) -> usize {
    p.cg() * p.kh * p.kw * p.b * p.ho() * p.wo()
}

/// Map an address of group `g`'s virtual inference matrix B to the
/// compact input address, or `None` inside the padding halo.
#[inline]
pub fn map_addr(addr_in: usize, p: &ConvParams, g: usize) -> Option<usize> {
    let (ho, wo) = (p.ho(), p.wo());
    let cols = p.b * ho * wo;
    let (row, col) = (addr_in / cols, addr_in % cols);
    let (c, rem) = (row / (p.kh * p.kw), row % (p.kh * p.kw));
    let (kh, kw) = (rem / p.kw, rem % p.kw);
    let (b, rem) = (col / (ho * wo), col % (ho * wo));
    let (oh, ow) = (rem / wo, rem % wo);
    // Input pixel = (oh*Sh + kh*Dh - Ph, ow*Sw + kw*Dw - Pw); NZ
    // detection is the padding bounds check only.
    let h = (oh * p.sh + kh * p.dh) as isize - p.ph as isize;
    let w = (ow * p.sw + kw * p.dw) as isize - p.pw as isize;
    if h < 0 || w < 0 || h as usize >= p.hi || w as usize >= p.wi {
        return None;
    }
    let c_abs = g * p.cg() + c;
    Some(((b * p.c + c_abs) * p.hi + h as usize) * p.wi + w as usize)
}

/// Materialize group `g`'s lowered inference matrix B through the
/// implicit mapping.
pub fn gather_matrix(x: &Tensor4, p: &ConvParams, g: usize) -> Matrix {
    assert_eq!(x.dims, [p.b, p.c, p.hi, p.wi]);
    let rows = p.cg() * p.kh * p.kw;
    let cols = p.b * p.ho() * p.wo();
    let mut m = Matrix::zeros(rows, cols);
    for (addr_in, out) in m.data.iter_mut().enumerate() {
        if let Some(a) = map_addr(addr_in, p, g) {
            *out = x.data[a];
        }
    }
    m
}

/// Lowered dynamic matrix A of group `g`: the group's kernel rows,
/// flattened `[N/G x (C/G)*Kh*Kw]` (dense).
pub fn lower_fwd_a(w: &Tensor4, p: &ConvParams, g: usize) -> Matrix {
    assert_eq!(w.dims, [p.n, p.cg(), p.kh, p.kw]);
    assert!(g < p.groups);
    let (ng, row_len) = (p.ng(), p.cg() * p.kh * p.kw);
    Matrix {
        rows: ng,
        cols: row_len,
        data: w.data[g * ng * row_len..(g + 1) * ng * row_len].to_vec(),
    }
}

/// Forward convolution via the implicit-im2col GEMMs.
pub fn fwd_calc(x: &Tensor4, w: &Tensor4, p: &ConvParams) -> Tensor4 {
    let (ho, wo) = (p.ho(), p.wo());
    let ng = p.ng();
    let mut y = Tensor4::zeros([p.b, p.n, ho, wo]);
    for g in 0..p.groups {
        let a = lower_fwd_a(w, p, g);
        let b = gather_matrix(x, p, g);
        let yg = a.matmul(&b); // [N/G x B*Ho*Wo]
        for n in 0..ng {
            for bi in 0..p.b {
                for h in 0..ho {
                    for ww in 0..wo {
                        y[(bi, g * ng + n, h, ww)] = yg[(n, (bi * ho + h) * wo + ww)];
                    }
                }
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv2d_fwd;
    use crate::tensor::Rng;

    fn check(p: ConvParams, seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor4::random([p.b, p.c, p.hi, p.wi], &mut rng);
        let w = Tensor4::random([p.n, p.cg(), p.kh, p.kw], &mut rng);
        let got = fwd_calc(&x, &w, &p);
        let want = conv2d_fwd(&x, &w, &p);
        assert!(got.max_abs_diff(&want) < 1e-4, "{p:?}");
    }

    #[test]
    fn fwd_gemm_matches_oracle_stride2() {
        check(ConvParams::basic(2, 2, 9, 9, 3, 3, 3, 2, 1, 1), 70);
    }

    #[test]
    fn fwd_gemm_matches_oracle_stride1_pad2() {
        check(ConvParams::basic(1, 2, 7, 7, 2, 3, 3, 1, 2, 2), 71);
    }

    #[test]
    fn fwd_gemm_matches_oracle_stride4_11x11() {
        // AlexNet-like stem.
        check(ConvParams::basic(1, 1, 19, 19, 2, 5, 5, 4, 2, 2), 72);
    }

    #[test]
    fn fwd_gemm_matches_oracle_asymmetric_stride() {
        check(ConvParams::basic(1, 2, 9, 12, 2, 3, 3, 1, 1, 1).with_stride(2, 3), 73);
    }

    #[test]
    fn fwd_gemm_matches_oracle_dilated() {
        check(ConvParams::basic(1, 2, 11, 11, 2, 3, 3, 1, 2, 2).with_dilation(2, 2), 74);
    }

    #[test]
    fn fwd_gemm_matches_oracle_grouped() {
        check(ConvParams::basic(1, 4, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(2), 75);
        check(ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(4), 76);
    }

    #[test]
    fn padding_zeros_only() {
        // With Ph = Pw = 0 the inference matrix has no structural zeros.
        let p = ConvParams::basic(1, 2, 8, 8, 2, 3, 3, 2, 0, 0);
        let nz = (0..virtual_len(&p)).filter(|a| map_addr(*a, &p, 0).is_some()).count();
        assert_eq!(nz, virtual_len(&p));
    }

    #[test]
    fn halo_fraction_small() {
        // Padding sparsity is far below the backprop regime's 75 %+.
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        let nz = (0..virtual_len(&p).min(4_000_000))
            .filter(|a| map_addr(*a, &p, 0).is_some())
            .count();
        let frac = 1.0 - nz as f64 / virtual_len(&p).min(4_000_000) as f64;
        assert!(frac < 0.10, "{frac}");
    }
}
