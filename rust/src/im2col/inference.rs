//! Implicit im2col of the *inference* pass — the mode the traditional
//! accelerator was designed around ("state-of-the-art systolic
//! array-based accelerators adopt the traditional im2col algorithm to
//! accelerate the inference of convolutional layers").
//!
//! Inference lowers `Y = X * W` to `A[N x C*Kh*Kw] . B[C*Kh*Kw x B*Ho*Wo]`
//! where B is the im2col of the *padded* input. The only structural
//! zeros are the padding halo, detected with two comparators per axis —
//! this is the 51-cycle stationary pipeline of Table III, shared by both
//! modes. Implemented here so the repo covers the full training step
//! (fwd + loss + grad) and the coordinator can report whole-step costs.

use crate::conv::ConvParams;
use crate::tensor::{Matrix, Tensor4};

/// Virtual matrix B dimensions for inference: `(C*Kh*Kw) x (B*Ho*Wo)`.
pub const fn virtual_len(p: &ConvParams) -> usize {
    p.c * p.kh * p.kw * p.b * p.ho() * p.wo()
}

/// Map an address of the virtual inference matrix B to the compact input
/// address, or `None` inside the padding halo.
#[inline]
pub fn map_addr(addr_in: usize, p: &ConvParams) -> Option<usize> {
    let (ho, wo) = (p.ho(), p.wo());
    let cols = p.b * ho * wo;
    let (row, col) = (addr_in / cols, addr_in % cols);
    let (c, rem) = (row / (p.kh * p.kw), row % (p.kh * p.kw));
    let (kh, kw) = (rem / p.kw, rem % p.kw);
    let (b, rem) = (col / (ho * wo), col % (ho * wo));
    let (oh, ow) = (rem / wo, rem % wo);
    // Input pixel = (oh*S + kh - Ph, ow*S + kw - Pw); NZ detection is the
    // padding bounds check only.
    let h = (oh * p.s + kh) as isize - p.ph as isize;
    let w = (ow * p.s + kw) as isize - p.pw as isize;
    if h < 0 || w < 0 || h as usize >= p.hi || w as usize >= p.wi {
        return None;
    }
    Some(((b * p.c + c) * p.hi + h as usize) * p.wi + w as usize)
}

/// Materialize the lowered inference matrix B through the implicit
/// mapping.
pub fn gather_matrix(x: &Tensor4, p: &ConvParams) -> Matrix {
    assert_eq!(x.dims, [p.b, p.c, p.hi, p.wi]);
    let rows = p.c * p.kh * p.kw;
    let cols = p.b * p.ho() * p.wo();
    let mut m = Matrix::zeros(rows, cols);
    for (addr_in, out) in m.data.iter_mut().enumerate() {
        if let Some(a) = map_addr(addr_in, p) {
            *out = x.data[a];
        }
    }
    m
}

/// Lowered dynamic matrix A of inference: the kernel, flattened
/// `[N x C*Kh*Kw]` (dense).
pub fn lower_fwd_a(w: &Tensor4, p: &ConvParams) -> Matrix {
    assert_eq!(w.dims, [p.n, p.c, p.kh, p.kw]);
    Matrix { rows: p.n, cols: p.c * p.kh * p.kw, data: w.data.clone() }
}

/// Forward convolution via the implicit-im2col GEMM.
pub fn fwd_calc(x: &Tensor4, w: &Tensor4, p: &ConvParams) -> Tensor4 {
    let a = lower_fwd_a(w, p);
    let b = gather_matrix(x, p);
    let y = a.matmul(&b); // [N x B*Ho*Wo]
    let (ho, wo) = (p.ho(), p.wo());
    Tensor4::from_fn([p.b, p.n, ho, wo], |bi, n, h, ww| y[(n, (bi * ho + h) * wo + ww)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv2d_fwd;
    use crate::tensor::Rng;

    fn check(p: ConvParams, seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor4::random([p.b, p.c, p.hi, p.wi], &mut rng);
        let w = Tensor4::random([p.n, p.c, p.kh, p.kw], &mut rng);
        let got = fwd_calc(&x, &w, &p);
        let want = conv2d_fwd(&x, &w, &p);
        assert!(got.max_abs_diff(&want) < 1e-4, "{p:?}");
    }

    #[test]
    fn fwd_gemm_matches_oracle_stride2() {
        check(ConvParams { b: 2, c: 2, hi: 9, wi: 9, n: 3, kh: 3, kw: 3, s: 2, ph: 1, pw: 1 }, 70);
    }

    #[test]
    fn fwd_gemm_matches_oracle_stride1_pad2() {
        check(ConvParams { b: 1, c: 2, hi: 7, wi: 7, n: 2, kh: 3, kw: 3, s: 1, ph: 2, pw: 2 }, 71);
    }

    #[test]
    fn fwd_gemm_matches_oracle_stride4_11x11() {
        // AlexNet-like stem.
        check(ConvParams { b: 1, c: 1, hi: 19, wi: 19, n: 2, kh: 5, kw: 5, s: 4, ph: 2, pw: 2 }, 72);
    }

    #[test]
    fn padding_zeros_only() {
        // With Ph = Pw = 0 the inference matrix has no structural zeros.
        let p = ConvParams { b: 1, c: 2, hi: 8, wi: 8, n: 2, kh: 3, kw: 3, s: 2, ph: 0, pw: 0 };
        let nz = (0..virtual_len(&p)).filter(|a| map_addr(*a, &p).is_some()).count();
        assert_eq!(nz, virtual_len(&p));
    }

    #[test]
    fn halo_fraction_small() {
        // Padding sparsity is far below the backprop regime's 75 %+.
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        let nz = (0..virtual_len(&p).min(4_000_000))
            .filter(|a| map_addr(*a, &p).is_some())
            .count();
        let frac = 1.0 - nz as f64 / virtual_len(&p).min(4_000_000) as f64;
        assert!(frac < 0.10, "{frac}");
    }
}
