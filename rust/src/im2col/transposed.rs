//! **Algorithm 1** — BP-im2col of transposed mode.
//!
//! During loss calculation the stationary matrix *B* is the im2col of the
//! zero-inserted + zero-padded loss map. BP-im2col never materializes
//! that map: given an address in the *virtual* matrix B, it recovers the
//! virtual pixel `(b, n, h, w)` of the zero-spaced map, classifies it
//! (NZ detection, generalized Eqs. 2–3, DESIGN.md §3), and for non-zero
//! pixels produces the address in the *compact* `[B,N,Ho,Wo]` loss map
//! actually stored on chip.
//!
//! Grouped layers run one virtual matrix per channel group `g`
//! (`(N/G)*Kh*Kw` rows); `G == 1, g == 0` is the paper's geometry.

use crate::conv::ConvParams;
use crate::im2col::Zone;
use crate::tensor::{Matrix, Tensor4};

/// A decoded pixel of the virtual stationary matrix B.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VirtualPixelB {
    /// Batch index (from the column).
    pub b: usize,
    /// Output-channel index *within the group* (from the row).
    pub n: usize,
    /// Row inside the virtual `Ho''' x Wo'''` zero-spaced channel.
    /// May exceed `Ho'''-1` when the forward floor-division is inexact;
    /// such pixels are always structural zeros.
    pub h: usize,
    /// Column inside the virtual zero-spaced channel (same caveat as
    /// `h`).
    pub w: usize,
}

/// Lines 1–4 of Algorithm 1: decompose a flat virtual-matrix address into
/// the virtual zero-spaced-map pixel it reads. Kernel taps are dilated:
/// `h = h0 + hk*Dh`, `w = w0 + wk*Dw`.
#[inline]
pub fn decompose(addr_in: usize, p: &ConvParams) -> VirtualPixelB {
    let cols = p.b * p.hi * p.wi;
    let (row, col) = (addr_in / cols, addr_in % cols);
    let b = col / (p.hi * p.wi);
    let (temp1, wk) = (row / p.kw, row % p.kw);
    let (n, hk) = (temp1 / p.kh, temp1 % p.kh);
    let temp2 = col % (p.hi * p.wi);
    let (h, w) = (temp2 / p.wi + hk * p.dh, temp2 % p.wi + wk * p.dw);
    VirtualPixelB { b, n, h, w }
}

/// NZ detection of transposed mode for a virtual pixel `(h, w)`:
/// generalized Eq. (2) (area 0 — upper/left padding, extent
/// `Dh(Kh-1)-Ph`), generalized Eq. (3) (area 1 — insertions, per-axis
/// strides), plus the bounds check for right/bottom padding
/// (DESIGN.md §3).
#[inline]
pub fn nz_detect(h: usize, w: usize, p: &ConvParams) -> Zone {
    let (eh, ew) = (p.ext_h(), p.ext_w());
    if h < eh || w < ew {
        return Zone::Area0; // Eq. (2)
    }
    if (h - eh) % p.sh > 0 || (w - ew) % p.sw > 0 {
        return Zone::Area1; // Eq. (3)
    }
    if (h - eh) / p.sh >= p.ho() || (w - ew) / p.sw >= p.wo() {
        return Zone::OutOfBounds; // right/bottom padding
    }
    Zone::NonZero
}

/// Full Algorithm 1: map an address of group `g`'s virtual matrix B to
/// the address in the compact loss map, or `None` for structural zeros.
#[inline]
pub fn map_addr(addr_in: usize, p: &ConvParams, g: usize) -> Option<usize> {
    let px = decompose(addr_in, p);
    if nz_detect(px.h, px.w, p).is_zero() {
        return None; // addr_out = NULL — zero-spaces
    }
    let (eh, ew) = (p.ext_h(), p.ext_w());
    let (h1, w1) = ((px.h - eh) / p.sh, (px.w - ew) / p.sw);
    let (ho, wo) = (p.ho(), p.wo());
    let n_abs = g * p.ng() + px.n;
    Some(px.b * p.n * ho * wo + n_abs * ho * wo + h1 * wo + w1)
}

/// Number of addresses in one group's virtual matrix B
/// (`((N/G)*Kh*Kw) x (B*Hi*Wi)`).
pub const fn virtual_len(p: &ConvParams) -> usize {
    p.ng() * p.kh * p.kw * p.b * p.hi * p.wi
}

/// Streaming address generator: yields `map_addr(addr, p, g)` for
/// `addr = 0, 1, 2, ...` without any division — the indices `(row, col,
/// b, h0, w0)` are carried as counters exactly like the hardware's
/// incrementers, and the per-row quantities (`n, hk, wk`, padding
/// offsets) are hoisted out of the inner loop. ~5x faster than calling
/// [`map_addr`] per address (EXPERIMENTS.md §Perf).
pub struct AddrGen<'a> {
    p: &'a ConvParams,
    /// Absolute output-channel index of the current row (`g*N/G + n`).
    n_abs: usize,
    /// Hoisted dilated kernel offsets (`hk*Dh`, `wk*Dw`).
    hk_off: usize,
    wk_off: usize,
    /// Column counters.
    b: usize,
    h0: usize,
    w0: usize,
    row: usize,
    rows: usize,
}

impl<'a> AddrGen<'a> {
    /// Streaming generator over group `g`'s virtual stationary matrix.
    pub fn new(p: &'a ConvParams, g: usize) -> Self {
        assert!(g < p.groups);
        Self {
            p,
            n_abs: g * p.ng(),
            hk_off: 0,
            wk_off: 0,
            b: 0,
            h0: 0,
            w0: 0,
            row: 0,
            rows: p.ng() * p.kh * p.kw,
        }
    }
}

impl Iterator for AddrGen<'_> {
    /// `Some(None)` = structural zero; `Some(Some(a))` = compact address.
    type Item = Option<usize>;

    #[inline]
    fn next(&mut self) -> Option<Option<usize>> {
        let p = self.p;
        if self.row == self.rows {
            return None;
        }
        let (h, w) = (self.h0 + self.hk_off, self.w0 + self.wk_off);
        let out = if nz_detect(h, w, p) == Zone::NonZero {
            let (eh, ew) = (p.ext_h(), p.ext_w());
            let (ho, wo) = (p.ho(), p.wo());
            Some(
                self.b * p.n * ho * wo
                    + self.n_abs * ho * wo
                    + (h - eh) / p.sh * wo
                    + (w - ew) / p.sw,
            )
        } else {
            None
        };
        // Increment the column counters (w0 fastest), then the row.
        self.w0 += 1;
        if self.w0 == p.wi {
            self.w0 = 0;
            self.h0 += 1;
            if self.h0 == p.hi {
                self.h0 = 0;
                self.b += 1;
                if self.b == p.b {
                    self.b = 0;
                    self.row += 1;
                    self.wk_off += p.dw;
                    if self.wk_off == p.kw * p.dw {
                        self.wk_off = 0;
                        self.hk_off += p.dh;
                        if self.hk_off == p.kh * p.dh {
                            self.hk_off = 0;
                            self.n_abs += 1;
                        }
                    }
                }
            }
        }
        Some(out)
    }
}

/// Materialize group `g`'s lowered matrix *functionally* through the
/// implicit mapping: every element is fetched from the compact `dY`
/// (flat NCHW buffer) via the streaming [`AddrGen`] (equivalent to
/// [`map_addr`] per address; see tests). This is what the accelerator
/// does in hardware; it must equal
/// [`crate::im2col::traditional::lower_loss_b`] over the reorganized map,
/// bit for bit.
pub fn gather_matrix(dy: &Tensor4, p: &ConvParams, g: usize) -> Matrix {
    assert_eq!(dy.dims, [p.b, p.n, p.ho(), p.wo()]);
    let rows = p.ng() * p.kh * p.kw;
    let cols = p.b * p.hi * p.wi;
    let mut m = Matrix::zeros(rows, cols);
    for (out, mapped) in m.data.iter_mut().zip(AddrGen::new(p, g)) {
        if let Some(addr_out) = mapped {
            *out = dy.data[addr_out];
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::{reorg, traditional};
    use crate::tensor::Rng;

    fn check_gather_equals_explicit(p: ConvParams, seed: u64) {
        let mut rng = Rng::new(seed);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let dyz = reorg::dilate_pad_loss(&dy, &p);
        for g in 0..p.groups {
            let implicit = gather_matrix(&dy, &p, g);
            let explicit = traditional::lower_loss_b(&dyz, &p, g);
            assert_eq!(implicit, explicit, "Algorithm 1 mismatch for {p:?} group {g}");
        }
    }

    #[test]
    fn alg1_equals_explicit_stride2_pad1() {
        check_gather_equals_explicit(ConvParams::basic(2, 2, 9, 9, 3, 3, 3, 2, 1, 1), 20);
    }

    #[test]
    fn alg1_equals_explicit_1x1_stride2() {
        check_gather_equals_explicit(ConvParams::basic(1, 3, 8, 8, 4, 1, 1, 2, 0, 0), 21);
    }

    #[test]
    fn alg1_equals_explicit_inexact_division() {
        check_gather_equals_explicit(ConvParams::basic(1, 1, 10, 10, 2, 3, 3, 2, 0, 0), 22);
    }

    #[test]
    fn alg1_equals_explicit_stride3_asymmetric() {
        check_gather_equals_explicit(ConvParams::basic(1, 1, 11, 8, 2, 3, 2, 3, 1, 0), 23);
    }

    #[test]
    fn alg1_equals_explicit_stride1() {
        // Degenerate S=1: no insertions, area 1 empty.
        check_gather_equals_explicit(ConvParams::basic(1, 1, 6, 6, 2, 3, 3, 1, 1, 1), 24);
    }

    #[test]
    fn alg1_equals_explicit_asymmetric_stride() {
        check_gather_equals_explicit(
            ConvParams::basic(1, 1, 9, 12, 2, 3, 3, 1, 1, 1).with_stride(2, 3),
            25,
        );
    }

    #[test]
    fn alg1_equals_explicit_dilated() {
        check_gather_equals_explicit(
            ConvParams::basic(1, 1, 11, 11, 2, 3, 3, 1, 2, 2).with_dilation(2, 2),
            26,
        );
        check_gather_equals_explicit(
            ConvParams::basic(1, 1, 12, 10, 2, 3, 2, 2, 1, 1).with_dilation(2, 3),
            27,
        );
    }

    #[test]
    fn alg1_equals_explicit_grouped() {
        check_gather_equals_explicit(ConvParams::basic(1, 4, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(2), 28);
        check_gather_equals_explicit(ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(4), 29);
    }

    #[test]
    fn decompose_matches_paper_notation() {
        // Hand-checked small case: Hi=Wi=4, Kh=Kw=2, B=1.
        let p = ConvParams::basic(1, 1, 4, 4, 2, 2, 2, 2, 0, 0);
        // addr 0 -> row 0 (n=0,hk=0,wk=0), col 0 (b=0,h0=0,w0=0) -> (h,w)=(0,0)
        assert_eq!(decompose(0, &p), VirtualPixelB { b: 0, n: 0, h: 0, w: 0 });
        // row 3 = n0,hk1,wk1; col 5 = h0=1,w0=1 -> h=2,w=2
        assert_eq!(decompose(3 * 16 + 5, &p), VirtualPixelB { b: 0, n: 0, h: 2, w: 2 });
        // row 4 -> n=1
        assert_eq!(decompose(4 * 16, &p).n, 1);
    }

    #[test]
    fn decompose_applies_dilation_to_kernel_taps() {
        let p = ConvParams::basic(1, 1, 5, 5, 1, 2, 2, 1, 1, 1).with_dilation(2, 2);
        // row 3 = hk=1, wk=1 -> offsets (2, 2).
        assert_eq!(decompose(3 * 25, &p), VirtualPixelB { b: 0, n: 0, h: 2, w: 2 });
    }

    #[test]
    fn nz_zones() {
        // Kh=Kw=3, P=0 -> padding extent 2; S=2.
        let p = ConvParams::basic(1, 1, 8, 8, 1, 3, 3, 2, 0, 0);
        assert_eq!(nz_detect(0, 5, &p), Zone::Area0); // h < 2
        assert_eq!(nz_detect(5, 1, &p), Zone::Area0); // w < 2
        assert_eq!(nz_detect(3, 2, &p), Zone::Area1); // (3-2)%2 = 1
        assert_eq!(nz_detect(2, 2, &p), Zone::NonZero); // maps to (0,0)
        // Ho = 3 -> offsets 0,2,4 valid; offset 6 -> h'=3 >= Ho.
        assert_eq!(nz_detect(8, 2, &p), Zone::OutOfBounds);
    }

    #[test]
    fn nz_zones_asymmetric_and_dilated() {
        // Sh=2, Sw=3; Dh=2 -> Eh = 2*2-1 = 3, Ew = 2-1 = 1.
        let p = ConvParams::basic(1, 1, 12, 12, 1, 3, 3, 1, 1, 1)
            .with_stride(2, 3)
            .with_dilation(2, 1);
        assert_eq!(p.ext_h(), 3);
        assert_eq!(p.ext_w(), 1);
        assert_eq!(nz_detect(2, 4, &p), Zone::Area0); // h < 3
        assert_eq!(nz_detect(4, 4, &p), Zone::Area1); // (4-3)%2 = 1
        assert_eq!(nz_detect(5, 2, &p), Zone::Area1); // (2-1)%3 = 1
        assert_eq!(nz_detect(5, 4, &p), Zone::NonZero); // ((5-3)/2, (4-1)/3) = (1,1)
    }

    #[test]
    fn addrgen_stream_equals_map_addr() {
        for p in [
            ConvParams::basic(2, 1, 9, 9, 2, 3, 3, 2, 1, 1),
            ConvParams::basic(1, 1, 8, 8, 3, 1, 1, 2, 0, 0),
            ConvParams::basic(1, 1, 10, 7, 2, 3, 2, 3, 1, 0),
            ConvParams::basic(1, 1, 9, 11, 2, 3, 3, 1, 1, 1).with_stride(2, 3),
            ConvParams::basic(1, 1, 11, 11, 2, 3, 3, 2, 2, 2).with_dilation(2, 2),
        ] {
            let stream: Vec<Option<usize>> = AddrGen::new(&p, 0).collect();
            assert_eq!(stream.len(), virtual_len(&p));
            for (addr, got) in stream.into_iter().enumerate() {
                assert_eq!(got, map_addr(addr, &p, 0), "{p:?} addr {addr}");
            }
        }
    }

    #[test]
    fn addrgen_stream_equals_map_addr_grouped() {
        let p = ConvParams::basic(1, 4, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(2);
        for g in 0..p.groups {
            let stream: Vec<Option<usize>> = AddrGen::new(&p, g).collect();
            assert_eq!(stream.len(), virtual_len(&p));
            for (addr, got) in stream.into_iter().enumerate() {
                assert_eq!(got, map_addr(addr, &p, g), "group {g} addr {addr}");
            }
        }
    }

    #[test]
    fn map_addr_compact_addresses_in_range() {
        let p = ConvParams::basic(2, 1, 9, 9, 2, 3, 3, 2, 1, 1);
        let compact = p.output_elems();
        let mut seen = vec![false; compact];
        for a in 0..virtual_len(&p) {
            if let Some(o) = map_addr(a, &p, 0) {
                assert!(o < compact, "address {o} out of compact range {compact}");
                seen[o] = true;
            }
        }
        // Every compact element is referenced at least once (each dY pixel
        // contributes to at least one dX pixel).
        assert!(seen.iter().all(|s| *s), "some compact addresses never referenced");
    }

    #[test]
    fn grouped_mapping_covers_only_group_channels() {
        let p = ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(2);
        let chan = p.ho() * p.wo();
        for g in 0..2 {
            for a in 0..virtual_len(&p) {
                if let Some(o) = map_addr(a, &p, g) {
                    let n = (o / chan) % p.n;
                    assert!(n / p.ng() == g, "group {g} mapped to channel {n}");
                }
            }
        }
    }
}
