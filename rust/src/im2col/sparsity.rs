//! Analytic zero counting of the lowered matrices — **structural**
//! sparsity.
//!
//! The crate models two entirely separate kinds of zeros, and this
//! module owns the first:
//!
//! * **Structural** sparsity (here): zeros that backpropagation
//!   *geometry* injects deterministically — dilation/insertion zeros of
//!   the gradient pass, padding zeros, out-of-bounds positions of the
//!   transposed mapping. They exist for every trained value of the
//!   tensors, their positions are closed-form functions of
//!   [`ConvParams`] alone, and BP-im2col's address generators skip them
//!   *exactly* (that is the paper's contribution).
//! * **Data** sparsity ([`crate::sparse`]): zeros in the tensor
//!   *values* — pruned weights, ReLU-sparse activations — governed by
//!   the statistical [`crate::sparse::Density`] knob and exploited (or
//!   not) by the configured [`crate::sparse::SparseLowering`]. Those
//!   zeros move with the data; only their *rate* is known analytically.
//!
//! The two compose: a sparse lowering operates on the matrices that
//! remain *after* structural zero-space is eliminated. The
//! [`crate::sparsity`] facade re-exports both sides.
//!
//! The paper's headline motivation (§I–II): for `stride >= 2` the lowered
//! matrix B of loss calculation is 75–93.91 % zeros and the lowered
//! matrix A of gradient calculation 74.8–93.6 %. Fig. 8 plots the same
//! numbers as the on-chip-bandwidth reduction. Counting by enumerating
//! the virtual matrices is O(10^8) per layer, so we count in
//! O(Hi*Kh + Wi*Kw) using separability of the NZ conditions. The counts
//! cover the generalized geometry: per-axis strides, kernel dilation and
//! channel groups (the zero *fraction* is group-independent — every
//! group's matrix has the same structural pattern).

use crate::conv::ConvParams;
use crate::im2col::{transposed, Zone};

/// Zero statistics of a lowered matrix (whole layer: all `G` groups).
///
/// Counts *structural* zeros only: positions the layer geometry forces
/// to zero regardless of the tensor values. Value zeros (pruning, ReLU)
/// are a [`crate::sparse::Density`] property and never appear here —
/// a fully dense layer can still be > 90 % structurally sparse.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SparsityStats {
    /// Total elements of the virtual matrix (summed over groups).
    pub total: usize,
    /// Structural non-zeros (stored pixels referenced).
    pub nonzero: usize,
}

impl SparsityStats {
    /// Fraction of structural zeros in `[0, 1]`.
    pub fn sparsity(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        1.0 - self.nonzero as f64 / self.total as f64
    }
}

/// Count of valid `h` (or `w`) positions per kernel offset for the
/// transposed mode: for fixed `hk`, how many `h0 in [0, Hi)` make
/// `h0 + hk*D` a stored pixel.
fn valid_count_1d(len_in: usize, k: usize, pad: usize, s: usize, d: usize, out: usize) -> usize {
    let e = d * (k - 1) - pad;
    let mut count = 0;
    for kk in 0..k {
        for i0 in 0..len_in {
            let h = i0 + kk * d;
            if h < e {
                continue;
            }
            let off = h - e;
            if off % s == 0 && off / s < out {
                count += 1;
            }
        }
    }
    count
}

/// Sparsity of the loss-calculation stationary matrix B
/// (`G` group matrices of `((N/G)*Kh*Kw) x (B*Hi*Wi)`), counting
/// structural zeros only.
pub fn loss_matrix_b(p: &ConvParams) -> SparsityStats {
    let total = p.groups * transposed::virtual_len(p);
    // The NZ condition is separable in (h0, hk) and (w0, wk); rows
    // factor as N * (Kh x Kw) over all groups, columns as B * (Hi x Wi).
    let vh = valid_count_1d(p.hi, p.kh, p.ph, p.sh, p.dh, p.ho());
    let vw = valid_count_1d(p.wi, p.kw, p.pw, p.sw, p.dw, p.wo());
    SparsityStats { total, nonzero: p.b * p.n * vh * vw }
}

/// Sparsity of the gradient-calculation dynamic matrix A
/// (`G` group matrices of `(N/G) x (B*Ho''*Wo'')`): every compact pixel
/// appears exactly once, so `nnz = B*N*Ho*Wo` exactly.
pub fn grad_matrix_a(p: &ConvParams) -> SparsityStats {
    SparsityStats {
        total: p.n * p.b * p.ho2() * p.wo2(),
        nonzero: p.b * p.n * p.ho() * p.wo(),
    }
}

/// Zero fraction contributed by zero-padding in the gradient-calculation
/// stationary matrix B (`G` group matrices of
/// `(B*Ho''*Wo'') x ((C/G)*Kh*Kw)`) — the inference-like padding zeros,
/// much smaller than the insertion zeros of matrix A.
pub fn grad_matrix_b(p: &ConvParams) -> SparsityStats {
    let (h2, w2) = (p.ho2(), p.wo2());
    let total = p.b * h2 * w2 * p.c * p.kh * p.kw;
    // Element (b,h,w),(c,kh,kw) reads Xpad[b, c, kh*Dh+h, kw*Dw+w]; it is
    // a structural (padding) zero unless Ph <= kh*Dh+h < Hi+Ph.
    let mut vh = 0usize;
    for kh in 0..p.kh {
        for h in 0..h2 {
            let r = kh * p.dh + h;
            if r >= p.ph && r < p.hi + p.ph {
                vh += 1;
            }
        }
    }
    let mut vw = 0usize;
    for kw in 0..p.kw {
        for w in 0..w2 {
            let r = kw * p.dw + w;
            if r >= p.pw && r < p.wi + p.pw {
                vw += 1;
            }
        }
    }
    SparsityStats { total, nonzero: p.b * p.c * vh * vw }
}

/// Brute-force recount of [`loss_matrix_b`] by enumerating the mapping —
/// O(virtual size); used by tests and small layers only. Every group has
/// the identical structural pattern, so group 0 is enumerated and scaled.
pub fn loss_matrix_b_brute(p: &ConvParams) -> SparsityStats {
    let per_group = transposed::virtual_len(p);
    let nonzero_g0 =
        (0..per_group).filter(|a| transposed::map_addr(*a, p, 0).is_some()).count();
    SparsityStats { total: p.groups * per_group, nonzero: p.groups * nonzero_g0 }
}

/// Zone histogram of the loss-mode virtual matrix: how many pixels fall
/// in area 0 / area 1 / out-of-bounds / non-zero, over all groups. Used
/// by reports.
pub fn loss_zone_histogram(p: &ConvParams) -> [usize; 4] {
    let mut hist = [0usize; 4];
    for a in 0..transposed::virtual_len(p) {
        let px = transposed::decompose(a, p);
        let z = transposed::nz_detect(px.h, px.w, p);
        let idx = match z {
            Zone::Area0 => 0,
            Zone::Area1 => 1,
            Zone::OutOfBounds => 2,
            Zone::NonZero => 3,
        };
        hist[idx] += p.groups;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_matches_brute_force() {
        for p in [
            ConvParams::basic(2, 2, 9, 9, 3, 3, 3, 2, 1, 1),
            ConvParams::basic(1, 3, 8, 8, 4, 1, 1, 2, 0, 0),
            ConvParams::basic(1, 1, 10, 10, 2, 3, 3, 2, 0, 0),
            ConvParams::basic(1, 1, 11, 8, 2, 3, 2, 3, 1, 0),
            ConvParams::basic(1, 1, 9, 12, 2, 3, 3, 1, 1, 1).with_stride(2, 3),
            ConvParams::basic(1, 1, 11, 11, 2, 3, 3, 2, 2, 2).with_dilation(2, 2),
            ConvParams::basic(1, 4, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(2),
            ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(4),
        ] {
            assert_eq!(loss_matrix_b(&p), loss_matrix_b_brute(&p), "analytic != brute for {p:?}");
        }
    }

    #[test]
    fn paper_sparsity_claim_stride2_layers() {
        // §II: 75–93.91 % for loss, 74.8–93.6 % for grad on stride>=2
        // layers of popular CNNs. Spot-check Table II's layers.
        for p in [
            ConvParams::square(224, 3, 64, 3, 2, 0),
            ConvParams::square(112, 64, 64, 3, 2, 1),
            ConvParams::square(56, 256, 512, 1, 2, 0),
            ConvParams::square(28, 244, 244, 3, 2, 1),
            ConvParams::square(14, 1024, 2048, 1, 2, 0),
        ] {
            let s_loss = loss_matrix_b(&p).sparsity();
            let s_grad = grad_matrix_a(&p).sparsity();
            assert!(s_loss > 0.70 && s_loss < 0.96, "{}: loss sparsity {s_loss}", p.id());
            assert!(s_grad > 0.70 && s_grad < 0.96, "{}: grad sparsity {s_grad}", p.id());
        }
    }

    #[test]
    fn grad_a_sparsity_closed_form() {
        let p = ConvParams::square(56, 256, 512, 1, 2, 0);
        let s = grad_matrix_a(&p);
        let expect = 1.0 - (28.0 * 28.0) / (55.0 * 55.0);
        assert!((s.sparsity() - expect).abs() < 1e-12);
    }

    #[test]
    fn grad_a_sparsity_asymmetric_stride() {
        // 1 - (Ho*Wo)/(Ho''*Wo'') with independent per-axis insertion.
        let p = ConvParams::basic(1, 1, 9, 12, 1, 3, 3, 1, 1, 1).with_stride(2, 3);
        let s = grad_matrix_a(&p);
        let expect = 1.0
            - (p.ho() * p.wo()) as f64 / (p.ho2() * p.wo2()) as f64;
        assert!((s.sparsity() - expect).abs() < 1e-12);
    }

    #[test]
    fn sparsity_fraction_is_group_independent() {
        let dense = ConvParams::square(56, 128, 128, 3, 2, 1);
        let grouped = dense.with_groups(32);
        assert!((loss_matrix_b(&dense).sparsity() - loss_matrix_b(&grouped).sparsity()).abs() < 1e-12);
        assert!((grad_matrix_a(&dense).sparsity() - grad_matrix_a(&grouped).sparsity()).abs() < 1e-12);
    }

    #[test]
    fn grad_b_padding_sparsity_small() {
        // Padding zeros are a small fraction (inference-like).
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        let s = grad_matrix_b(&p);
        assert!(s.sparsity() < 0.10, "padding sparsity {}", s.sparsity());
    }

    #[test]
    fn zone_histogram_sums_to_total() {
        let p = ConvParams::basic(1, 1, 9, 9, 2, 3, 3, 2, 1, 1);
        let hist = loss_zone_histogram(&p);
        assert_eq!(hist.iter().sum::<usize>(), p.groups * transposed::virtual_len(&p));
        assert_eq!(hist[3], loss_matrix_b(&p).nonzero);
    }

    use crate::conv::ConvParams;
}
