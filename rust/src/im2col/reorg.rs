//! Explicit zero-space data reorganization — what the *baseline*
//! accelerator must do before it can run traditional im2col on
//! backpropagation, and exactly the work BP-im2col eliminates.
//!
//! Generalized geometry (DESIGN.md §2): zero-insertion uses the per-axis
//! strides `(Sh, Sw)` and the loss-map padding extent is the dilated
//! kernel reach `Dh(Kh-1) - Ph` / `Dw(Kw-1) - Pw`.

use crate::conv::ConvParams;
use crate::tensor::Tensor4;

/// Zero-insert (dilate by `(Sh, Sw)`) and zero-pad (by
/// `(Dh(Kh-1)-Ph, Dw(Kw-1)-Pw)`) the loss of the output, producing the
/// `[B, N, Ho''', Wo''']` map used by **loss calculation** (`ei`
/// subscript in the paper's Eq. 1).
pub fn dilate_pad_loss(dy: &Tensor4, p: &ConvParams) -> Tensor4 {
    assert_eq!(dy.dims, [p.b, p.n, p.ho(), p.wo()]);
    let (eh, ew) = (p.ext_h(), p.ext_w());
    let mut out = Tensor4::zeros([p.b, p.n, p.ho3(), p.wo3()]);
    for b in 0..p.b {
        for n in 0..p.n {
            for h in 0..p.ho() {
                for w in 0..p.wo() {
                    out[(b, n, eh + h * p.sh, ew + w * p.sw)] = dy[(b, n, h, w)];
                }
            }
        }
    }
    out
}

/// Zero-insert only (no padding), producing the `[B, N, Ho'', Wo'']` map
/// used by **gradient calculation** (`i` subscript in Eq. 1).
pub fn dilate_loss(dy: &Tensor4, p: &ConvParams) -> Tensor4 {
    assert_eq!(dy.dims, [p.b, p.n, p.ho(), p.wo()]);
    let mut out = Tensor4::zeros([p.b, p.n, p.ho2(), p.wo2()]);
    for b in 0..p.b {
        for n in 0..p.n {
            for h in 0..p.ho() {
                for w in 0..p.wo() {
                    out[(b, n, h * p.sh, w * p.sw)] = dy[(b, n, h, w)];
                }
            }
        }
    }
    out
}

/// Zero-pad the input by `(Ph, Pw)` (`e` subscript in Eq. 1), used by the
/// gradient calculation's stationary matrix.
pub fn pad_input(x: &Tensor4, p: &ConvParams) -> Tensor4 {
    assert_eq!(x.dims, [p.b, p.c, p.hi, p.wi]);
    let mut out = Tensor4::zeros([p.b, p.c, p.hi + 2 * p.ph, p.wi + 2 * p.pw]);
    for b in 0..p.b {
        for c in 0..p.c {
            for h in 0..p.hi {
                for w in 0..p.wi {
                    out[(b, c, h + p.ph, w + p.pw)] = x[(b, c, h, w)];
                }
            }
        }
    }
    out
}

/// `Tr(rot180 ∘ W)`: rotate each `Kh x Kw` plane by 180° and swap the
/// channel dimensions, yielding the `[C, N, Kh, Kw]` kernel of the
/// transposed convolution (ungrouped layers). Dense — no zero spaces —
/// so both the baseline and BP-im2col use it as-is for the dynamic
/// matrix of loss calculation.
pub fn rot180_transpose(w: &Tensor4) -> Tensor4 {
    let [n, c, kh, kw] = w.dims;
    Tensor4::from_fn([c, n, kh, kw], |ci, ni, h, x| w[(ni, ci, kh - 1 - h, kw - 1 - x)])
}

/// Per-group `Tr(rot180 ∘ W)`: from the grouped kernel `[N, C/G, Kh, Kw]`
/// extract group `g`'s `[C/G, N/G, Kh, Kw]` transposed-rotated kernel.
/// For `G == 1` this equals [`rot180_transpose`].
pub fn rot180_transpose_group(w: &Tensor4, p: &ConvParams, g: usize) -> Tensor4 {
    assert_eq!(w.dims, [p.n, p.cg(), p.kh, p.kw]);
    assert!(g < p.groups);
    let (kh, kw, ng) = (p.kh, p.kw, p.ng());
    Tensor4::from_fn([p.cg(), ng, kh, kw], |ci, ni, h, x| {
        w[(g * ng + ni, ci, kh - 1 - h, kw - 1 - x)]
    })
}

/// Elements written by the loss-calculation reorganization pass
/// (size of the zero-spaced map the baseline materializes off-chip).
pub const fn loss_reorg_elems(p: &ConvParams) -> usize {
    p.b * p.n * p.ho3() * p.wo3()
}

/// Elements written by the gradient-calculation reorganization pass.
pub const fn grad_reorg_elems(p: &ConvParams) -> usize {
    p.b * p.n * p.ho2() * p.wo2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn params() -> ConvParams {
        ConvParams::basic(1, 2, 7, 7, 3, 3, 3, 2, 1, 1)
    }

    #[test]
    fn dilate_pad_shapes_and_placement() {
        let p = params();
        let mut rng = Rng::new(0);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let z = dilate_pad_loss(&dy, &p);
        assert_eq!(z.dims, [p.b, p.n, p.ho3(), p.wo3()]);
        // Every original element lands at (K-1-P + h*S).
        for h in 0..p.ho() {
            for w in 0..p.wo() {
                assert_eq!(z[(0, 1, 1 + 2 * h, 1 + 2 * w)], dy[(0, 1, h, w)]);
            }
        }
        // Zero count: all but the originals.
        let nz = dy.len() - dy.count_zeros();
        assert_eq!(z.len() - z.count_zeros(), nz);
    }

    #[test]
    fn dilate_only_shape() {
        let p = params();
        let mut rng = Rng::new(1);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let z = dilate_loss(&dy, &p);
        assert_eq!(z.dims, [p.b, p.n, p.ho2(), p.wo2()]);
        assert_eq!(z[(0, 2, 2, 4)], dy[(0, 2, 1, 2)]);
        // Inserted rows are entirely zero.
        for w in 0..p.wo2() {
            assert_eq!(z[(0, 0, 1, w)], 0.0);
        }
    }

    #[test]
    fn dilate_asymmetric_stride_placement() {
        let p = ConvParams::basic(1, 1, 9, 12, 1, 3, 3, 1, 1, 1).with_stride(2, 3);
        let mut rng = Rng::new(5);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let z = dilate_loss(&dy, &p);
        assert_eq!(z.dims, [1, 1, p.ho2(), p.wo2()]);
        assert_eq!(z[(0, 0, 2, 3)], dy[(0, 0, 1, 1)]);
        assert_eq!(z[(0, 0, 2, 1)], 0.0); // 1 % Sw != 0
    }

    #[test]
    fn dilate_pad_dilated_kernel_extent() {
        // Dh = 2, Ph = 1: padding extent Dh(Kh-1)-Ph = 3.
        let p = ConvParams::basic(1, 1, 9, 9, 1, 3, 3, 1, 1, 1).with_dilation(2, 2);
        let mut rng = Rng::new(6);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let z = dilate_pad_loss(&dy, &p);
        assert_eq!(p.ext_h(), 3);
        assert_eq!(z.dims, [1, 1, p.ho() + 6, p.wo() + 6]);
        assert_eq!(z[(0, 0, 3, 3)], dy[(0, 0, 0, 0)]);
    }

    #[test]
    fn pad_input_border_zero() {
        let p = params();
        let mut rng = Rng::new(2);
        let x = Tensor4::random([p.b, p.c, p.hi, p.wi], &mut rng);
        let xp = pad_input(&x, &p);
        assert_eq!(xp.dims, [1, 2, 9, 9]);
        assert_eq!(xp[(0, 0, 0, 0)], 0.0);
        assert_eq!(xp[(0, 1, 1, 1)], x[(0, 1, 0, 0)]);
        assert_eq!(xp[(0, 1, 8, 8)], 0.0);
    }

    #[test]
    fn rot180_transpose_involution_on_values() {
        let mut rng = Rng::new(3);
        let w = Tensor4::random([3, 2, 3, 3], &mut rng);
        let r = rot180_transpose(&w);
        assert_eq!(r.dims, [2, 3, 3, 3]);
        assert_eq!(r[(1, 2, 0, 0)], w[(2, 1, 2, 2)]);
        // Applying it twice returns the original.
        assert_eq!(rot180_transpose(&r), w);
    }

    #[test]
    fn rot180_group_matches_ungrouped_when_g1() {
        let p = ConvParams::basic(1, 2, 7, 7, 3, 3, 3, 2, 1, 1);
        let mut rng = Rng::new(4);
        let w = Tensor4::random([p.n, p.cg(), p.kh, p.kw], &mut rng);
        assert_eq!(rot180_transpose_group(&w, &p, 0), rot180_transpose(&w));
    }

    #[test]
    fn rot180_group_selects_group_channels() {
        let p = ConvParams::basic(1, 4, 7, 7, 6, 3, 3, 2, 1, 1).with_groups(2);
        let mut rng = Rng::new(7);
        let w = Tensor4::random([p.n, p.cg(), p.kh, p.kw], &mut rng);
        let r1 = rot180_transpose_group(&w, &p, 1);
        assert_eq!(r1.dims, [2, 3, 3, 3]);
        // Group 1's output channels are 3..6.
        assert_eq!(r1[(0, 0, 0, 0)], w[(3, 0, 2, 2)]);
        assert_eq!(r1[(1, 2, 1, 2)], w[(5, 1, 1, 0)]);
    }

    #[test]
    fn reorg_elem_counts_match_table1_symbols() {
        // Layer 224/3/64/3/2/0 of Table II: Ho''' = 225.
        let p = ConvParams::square(224, 3, 64, 3, 2, 0);
        assert_eq!(loss_reorg_elems(&p), 2 * 64 * 225 * 225);
        assert_eq!(grad_reorg_elems(&p), 2 * 64 * 221 * 221);
    }
}
