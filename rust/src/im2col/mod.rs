//! The paper's contribution, as software: lowering convolution
//! backpropagation to GEMM with and without zero-space materialization.
//!
//! * [`reorg`] — the *baseline's* explicit data reorganization:
//!   zero-insertion (dilation by the forward stride) and zero-padding of
//!   the loss map, padding of the input, `rot180 ∘ Tr` of the kernel.
//! * [`traditional`] — traditional explicit im2col over the reorganized
//!   (zero-spaced) tensors.
//! * [`transposed`] — **Algorithm 1**: BP-im2col address mapping of the
//!   stationary matrix *B* during loss calculation (transposed-convolution
//!   mode), with NZ detection per Eqs. (2)–(3).
//! * [`dilated`] — **Algorithm 2**: BP-im2col address mapping of the
//!   dynamic matrix *A* during gradient calculation (dilated-convolution
//!   mode), with NZ detection per Eq. (4).
//! * [`pipeline`] — end-to-end functional loss/gradient calculation via
//!   either path, plus un-lowering of GEMM outputs back to tensors.
//! * [`sparsity`] — analytic zero counting of the lowered matrices
//!   (the paper's 75–93.91 % claims, Fig. 8's sparsity series).

pub mod dilated;
pub mod inference;
pub mod pipeline;
pub mod reorg;
pub mod sparsity;
pub mod traditional;
pub mod transposed;

/// Result of NZ detection for one virtual-matrix pixel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Zone {
    /// Upper/left zero-padding (Eq. 2) — "area 0" in the paper.
    Area0,
    /// Zero-insertion rows/columns (Eq. 3 / Eq. 4) — "area 1".
    Area1,
    /// Right/bottom padding that Eq. 3 alone does not flag: the stride
    /// divides the offset but the mapped index falls beyond `Ho`/`Wo`.
    /// (Needed for functional correctness; see DESIGN.md §1.)
    OutOfBounds,
    /// A stored, potentially non-zero pixel.
    NonZero,
}

impl Zone {
    /// True when the pixel is a structural zero (not stored on chip).
    #[inline]
    pub fn is_zero(self) -> bool {
        !matches!(self, Zone::NonZero)
    }
}
