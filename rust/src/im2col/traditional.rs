//! Traditional *explicit* im2col over the reorganized (zero-spaced)
//! tensors — the baseline the paper compares against, and the functional
//! specification the implicit mappings must reproduce bit-exactly.
//!
//! Grouped convolutions lower to `G` independent GEMMs (one per channel
//! group); every function here takes the group index `g` and produces
//! group `g`'s operand. `G == 1, g == 0` recovers the paper's whole-layer
//! matrices.

use crate::conv::ConvParams;
use crate::im2col::reorg;
use crate::tensor::{Matrix, Tensor4};

/// Lowered stationary matrix **B** of the loss calculation, group `g`:
/// `B[(n',kh,kw), (b,h0,w0)] = dYz[b, g*N/G+n', h0+kh*Dh, w0+kw*Dw]`
/// where `dYz` is the zero-inserted + zero-padded loss map
/// (`[B,N,Ho''',Wo''']`).
///
/// Reads outside `dYz` (possible when the forward floor-division is
/// inexact, so `h0+kh*Dh > Ho'''-1` for the last rows) are zero — those
/// virtual pixels correspond to input rows that never contributed to the
/// forward output.
pub fn lower_loss_b(dyz: &Tensor4, p: &ConvParams, g: usize) -> Matrix {
    assert_eq!(dyz.dims, [p.b, p.n, p.ho3(), p.wo3()]);
    assert!(g < p.groups);
    let ng = p.ng();
    let rows = ng * p.kh * p.kw;
    let cols = p.b * p.hi * p.wi;
    Matrix::from_fn(rows, cols, |row, col| {
        let (n, rem) = (row / (p.kh * p.kw), row % (p.kh * p.kw));
        let (kh, kw) = (rem / p.kw, rem % p.kw);
        let (b, rem) = (col / (p.hi * p.wi), col % (p.hi * p.wi));
        let (h0, w0) = (rem / p.wi, rem % p.wi);
        dyz.get_padded(b, g * ng + n, (h0 + kh * p.dh) as isize, (w0 + kw * p.dw) as isize)
    })
}

/// Lowered dynamic matrix **A** of the loss calculation, group `g`:
/// `A[c', (n',kh,kw)] = rot180(W_g)ᵀ[c', n', kh, kw]` — dense, no zero
/// spaces.
pub fn lower_loss_a(w: &Tensor4, p: &ConvParams, g: usize) -> Matrix {
    let wt = reorg::rot180_transpose_group(w, p, g);
    assert_eq!(wt.dims, [p.cg(), p.ng(), p.kh, p.kw]);
    Matrix { rows: p.cg(), cols: p.ng() * p.kh * p.kw, data: wt.data }
}

/// Lowered dynamic matrix **A** of the gradient calculation, group `g`:
/// `A[n', (b,h,w)] = dYd[b, g*N/G+n', h, w]` over the zero-inserted
/// `[B,N,Ho'',Wo'']` loss map (no im2col — the loss acts as the kernel).
pub fn lower_grad_a(dyd: &Tensor4, p: &ConvParams, g: usize) -> Matrix {
    let (h2, w2) = (p.ho2(), p.wo2());
    assert_eq!(dyd.dims, [p.b, p.n, h2, w2]);
    assert!(g < p.groups);
    let ng = p.ng();
    Matrix::from_fn(ng, p.b * h2 * w2, |n, col| {
        let (b, rem) = (col / (h2 * w2), col % (h2 * w2));
        let (h, w) = (rem / w2, rem % w2);
        dyd[(b, g * ng + n, h, w)]
    })
}

/// Lowered stationary matrix **B** of the gradient calculation, group
/// `g`: `B[(b,h,w), (c',kh,kw)] = Xpad[b, g*C/G+c', kh*Dh+h, kw*Dw+w]` —
/// the im2col of the padded input with an `Ho'' x Wo''`-step window,
/// stride 1, kernel taps dilated by `(Dh, Dw)`.
pub fn lower_grad_b(xpad: &Tensor4, p: &ConvParams, g: usize) -> Matrix {
    let (h2, w2) = (p.ho2(), p.wo2());
    assert_eq!(xpad.dims, [p.b, p.c, p.hi + 2 * p.ph, p.wi + 2 * p.pw]);
    assert!(g < p.groups);
    let cg = p.cg();
    Matrix::from_fn(p.b * h2 * w2, cg * p.kh * p.kw, |row, col| {
        let (b, rem) = (row / (h2 * w2), row % (h2 * w2));
        let (h, w) = (rem / w2, rem % w2);
        let (c, rem) = (col / (p.kh * p.kw), col % (p.kh * p.kw));
        let (kh, kw) = (rem / p.kw, rem % p.kw);
        xpad.get_padded(b, g * cg + c, (kh * p.dh + h) as isize, (kw * p.dw + w) as isize)
    })
}

/// Scatter group `g`'s loss-calculation GEMM output `[C/G x B*Hi*Wi]`
/// into the channels `g*C/G ..` of `dX [B,C,Hi,Wi]`.
pub fn loss_from_gemm_group(y: &Matrix, p: &ConvParams, g: usize, dx: &mut Tensor4) {
    assert_eq!((y.rows, y.cols), (p.cg(), p.b * p.hi * p.wi));
    assert_eq!(dx.dims, [p.b, p.c, p.hi, p.wi]);
    let cg = p.cg();
    for r in 0..cg {
        for b in 0..p.b {
            for h in 0..p.hi {
                for w in 0..p.wi {
                    dx[(b, g * cg + r, h, w)] = y[(r, b * p.hi * p.wi + h * p.wi + w)];
                }
            }
        }
    }
}

/// Scatter group `g`'s gradient-calculation GEMM output
/// `[N/G x (C/G)*Kh*Kw]` into the rows `g*N/G ..` of
/// `dW [N, C/G, Kh, Kw]`.
pub fn grad_from_gemm_group(y: &Matrix, p: &ConvParams, g: usize, dw: &mut Tensor4) {
    let (cg, ng) = (p.cg(), p.ng());
    assert_eq!((y.rows, y.cols), (ng, cg * p.kh * p.kw));
    assert_eq!(dw.dims, [p.n, cg, p.kh, p.kw]);
    let row_len = cg * p.kh * p.kw;
    for n in 0..ng {
        let dst = (g * ng + n) * row_len;
        dw.data[dst..dst + row_len].copy_from_slice(&y.data[n * row_len..(n + 1) * row_len]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{conv2d_bwd_input, conv2d_bwd_weight};
    use crate::tensor::Rng;

    fn check_loss(p: ConvParams, seed: u64) {
        let mut rng = Rng::new(seed);
        let w = Tensor4::random([p.n, p.cg(), p.kh, p.kw], &mut rng);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let dyz = reorg::dilate_pad_loss(&dy, &p);
        let mut dx = Tensor4::zeros([p.b, p.c, p.hi, p.wi]);
        for g in 0..p.groups {
            let a = lower_loss_a(&w, &p, g);
            let bm = lower_loss_b(&dyz, &p, g);
            loss_from_gemm_group(&a.matmul(&bm), &p, g, &mut dx);
        }
        let oracle = conv2d_bwd_input(&dy, &w, &p);
        assert!(dx.max_abs_diff(&oracle) < 1e-4, "loss GEMM != oracle for {p:?}");
    }

    fn check_grad(p: ConvParams, seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor4::random([p.b, p.c, p.hi, p.wi], &mut rng);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let dyd = reorg::dilate_loss(&dy, &p);
        let xp = reorg::pad_input(&x, &p);
        let mut dw = Tensor4::zeros([p.n, p.cg(), p.kh, p.kw]);
        for g in 0..p.groups {
            let a = lower_grad_a(&dyd, &p, g);
            let bm = lower_grad_b(&xp, &p, g);
            grad_from_gemm_group(&a.matmul(&bm), &p, g, &mut dw);
        }
        let oracle = conv2d_bwd_weight(&x, &dy, &p);
        assert!(dw.max_abs_diff(&oracle) < 1e-3, "grad GEMM != oracle for {p:?}");
    }

    #[test]
    fn loss_gemm_matches_oracle_stride2_pad1() {
        check_loss(ConvParams::basic(2, 2, 9, 9, 3, 3, 3, 2, 1, 1), 10);
    }

    #[test]
    fn loss_gemm_matches_oracle_1x1() {
        check_loss(ConvParams::basic(1, 3, 8, 8, 4, 1, 1, 2, 0, 0), 11);
    }

    #[test]
    fn loss_gemm_matches_oracle_inexact_division() {
        check_loss(ConvParams::basic(1, 2, 10, 10, 2, 3, 3, 2, 0, 0), 12);
    }

    #[test]
    fn loss_gemm_matches_oracle_stride3() {
        check_loss(ConvParams::basic(1, 2, 11, 8, 2, 3, 2, 3, 1, 0), 13);
    }

    #[test]
    fn loss_gemm_matches_oracle_asymmetric_stride() {
        check_loss(ConvParams::basic(1, 2, 9, 12, 2, 3, 3, 1, 1, 1).with_stride(2, 3), 18);
    }

    #[test]
    fn loss_gemm_matches_oracle_dilated() {
        check_loss(ConvParams::basic(1, 2, 11, 11, 2, 3, 3, 1, 2, 2).with_dilation(2, 2), 19);
    }

    #[test]
    fn loss_gemm_matches_oracle_grouped() {
        check_loss(ConvParams::basic(1, 4, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(2), 20);
        check_loss(ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(4), 21);
    }

    #[test]
    fn grad_gemm_matches_oracle_stride2_pad1() {
        check_grad(ConvParams::basic(2, 2, 9, 9, 3, 3, 3, 2, 1, 1), 14);
    }

    #[test]
    fn grad_gemm_matches_oracle_1x1() {
        check_grad(ConvParams::basic(1, 3, 8, 8, 4, 1, 1, 2, 0, 0), 15);
    }

    #[test]
    fn grad_gemm_matches_oracle_inexact_division() {
        check_grad(ConvParams::basic(1, 2, 10, 10, 2, 3, 3, 2, 0, 0), 16);
    }

    #[test]
    fn grad_gemm_matches_oracle_stride4() {
        check_grad(ConvParams::basic(1, 1, 12, 12, 2, 4, 4, 4, 0, 0), 17);
    }

    #[test]
    fn grad_gemm_matches_oracle_asymmetric_stride() {
        check_grad(ConvParams::basic(1, 2, 9, 12, 2, 3, 3, 1, 1, 1).with_stride(3, 2), 22);
    }

    #[test]
    fn grad_gemm_matches_oracle_dilated() {
        check_grad(ConvParams::basic(1, 2, 11, 11, 2, 3, 3, 1, 2, 2).with_dilation(2, 2), 23);
    }

    #[test]
    fn grad_gemm_matches_oracle_grouped() {
        check_grad(ConvParams::basic(1, 4, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(2), 24);
        check_grad(ConvParams::basic(1, 6, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(6), 25);
    }

    #[test]
    fn loss_b_sparsity_is_high_for_stride2() {
        // §I claim: >= ~75 % zeros for stride >= 2.
        let p = ConvParams::basic(1, 2, 16, 16, 2, 3, 3, 2, 1, 1);
        let mut rng = Rng::new(18);
        // Use all-nonzero dY so every zero in the matrix is structural.
        let dy = Tensor4::from_fn([p.b, p.n, p.ho(), p.wo()], |_, _, _, _| rng.range_f32(0.5, 1.0));
        let bm = lower_loss_b(&reorg::dilate_pad_loss(&dy, &p), &p, 0);
        assert!(bm.sparsity() > 0.70, "sparsity {}", bm.sparsity());
    }
}
