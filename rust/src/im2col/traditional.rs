//! Traditional *explicit* im2col over the reorganized (zero-spaced)
//! tensors — the baseline the paper compares against, and the functional
//! specification the implicit mappings must reproduce bit-exactly.

use crate::conv::ConvParams;
use crate::im2col::reorg;
use crate::tensor::{Matrix, Tensor4};

/// Lowered stationary matrix **B** of the loss calculation:
/// `B[(n,kh,kw), (b,h0,w0)] = dYz[b, n, h0+kh, w0+kw]` where `dYz` is the
/// zero-inserted + zero-padded loss map (`[B,N,Ho''',Wo''']`).
///
/// Reads outside `dYz` (possible when the forward floor-division is
/// inexact, so `h0+kh > Ho'''-1` for the last rows) are zero — those
/// virtual pixels correspond to input rows that never contributed to the
/// forward output.
pub fn lower_loss_b(dyz: &Tensor4, p: &ConvParams) -> Matrix {
    assert_eq!(dyz.dims, [p.b, p.n, p.ho3(), p.wo3()]);
    let rows = p.n * p.kh * p.kw;
    let cols = p.b * p.hi * p.wi;
    Matrix::from_fn(rows, cols, |row, col| {
        let (n, rem) = (row / (p.kh * p.kw), row % (p.kh * p.kw));
        let (kh, kw) = (rem / p.kw, rem % p.kw);
        let (b, rem) = (col / (p.hi * p.wi), col % (p.hi * p.wi));
        let (h0, w0) = (rem / p.wi, rem % p.wi);
        dyz.get_padded(b, n, (h0 + kh) as isize, (w0 + kw) as isize)
    })
}

/// Lowered dynamic matrix **A** of the loss calculation:
/// `A[c, (n,kh,kw)] = rot180(W)ᵀ[c, n, kh, kw]` — dense, no zero spaces.
pub fn lower_loss_a(w: &Tensor4, p: &ConvParams) -> Matrix {
    let wt = reorg::rot180_transpose(w);
    assert_eq!(wt.dims, [p.c, p.n, p.kh, p.kw]);
    Matrix { rows: p.c, cols: p.n * p.kh * p.kw, data: wt.data }
}

/// Lowered dynamic matrix **A** of the gradient calculation:
/// `A[n, (b,h,w)] = dYd[b, n, h, w]` over the zero-inserted
/// `[B,N,Ho'',Wo'']` loss map (no im2col — the loss acts as the kernel).
pub fn lower_grad_a(dyd: &Tensor4, p: &ConvParams) -> Matrix {
    let (h2, w2) = (p.ho2(), p.wo2());
    assert_eq!(dyd.dims, [p.b, p.n, h2, w2]);
    Matrix::from_fn(p.n, p.b * h2 * w2, |n, col| {
        let (b, rem) = (col / (h2 * w2), col % (h2 * w2));
        let (h, w) = (rem / w2, rem % w2);
        dyd[(b, n, h, w)]
    })
}

/// Lowered stationary matrix **B** of the gradient calculation:
/// `B[(b,h,w), (c,kh,kw)] = Xpad[b, c, kh+h, kw+w]` — the im2col of the
/// padded input with an `Ho'' x Wo''`-step window, stride 1.
pub fn lower_grad_b(xpad: &Tensor4, p: &ConvParams) -> Matrix {
    let (h2, w2) = (p.ho2(), p.wo2());
    assert_eq!(xpad.dims, [p.b, p.c, p.hi + 2 * p.ph, p.wi + 2 * p.pw]);
    Matrix::from_fn(p.b * h2 * w2, p.c * p.kh * p.kw, |row, col| {
        let (b, rem) = (row / (h2 * w2), row % (h2 * w2));
        let (h, w) = (rem / w2, rem % w2);
        let (c, rem) = (col / (p.kh * p.kw), col % (p.kh * p.kw));
        let (kh, kw) = (rem / p.kw, rem % p.kw);
        xpad.get_padded(b, c, (kh + h) as isize, (kw + w) as isize)
    })
}

/// Un-lower the loss-calculation GEMM output `[C x B*Hi*Wi]` to
/// `dX [B,C,Hi,Wi]`.
pub fn loss_from_gemm(y: &Matrix, p: &ConvParams) -> Tensor4 {
    assert_eq!((y.rows, y.cols), (p.c, p.b * p.hi * p.wi));
    Tensor4::from_fn([p.b, p.c, p.hi, p.wi], |b, c, h, w| y[(c, b * p.hi * p.wi + h * p.wi + w)])
}

/// Un-lower the gradient-calculation GEMM output `[N x C*Kh*Kw]` to
/// `dW [N,C,Kh,Kw]`.
pub fn grad_from_gemm(y: &Matrix, p: &ConvParams) -> Tensor4 {
    assert_eq!((y.rows, y.cols), (p.n, p.c * p.kh * p.kw));
    Tensor4 { dims: [p.n, p.c, p.kh, p.kw], data: y.data.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{conv2d_bwd_input, conv2d_bwd_weight};
    use crate::tensor::Rng;

    fn check_loss(p: ConvParams, seed: u64) {
        let mut rng = Rng::new(seed);
        let w = Tensor4::random([p.n, p.c, p.kh, p.kw], &mut rng);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let dyz = reorg::dilate_pad_loss(&dy, &p);
        let a = lower_loss_a(&w, &p);
        let bm = lower_loss_b(&dyz, &p);
        let dx = loss_from_gemm(&a.matmul(&bm), &p);
        let oracle = conv2d_bwd_input(&dy, &w, &p);
        assert!(dx.max_abs_diff(&oracle) < 1e-4, "loss GEMM != oracle for {p:?}");
    }

    fn check_grad(p: ConvParams, seed: u64) {
        let mut rng = Rng::new(seed);
        let x = Tensor4::random([p.b, p.c, p.hi, p.wi], &mut rng);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        let dyd = reorg::dilate_loss(&dy, &p);
        let xp = reorg::pad_input(&x, &p);
        let a = lower_grad_a(&dyd, &p);
        let bm = lower_grad_b(&xp, &p);
        let dw = grad_from_gemm(&a.matmul(&bm), &p);
        let oracle = conv2d_bwd_weight(&x, &dy, &p);
        assert!(dw.max_abs_diff(&oracle) < 1e-3, "grad GEMM != oracle for {p:?}");
    }

    #[test]
    fn loss_gemm_matches_oracle_stride2_pad1() {
        check_loss(ConvParams { b: 2, c: 2, hi: 9, wi: 9, n: 3, kh: 3, kw: 3, s: 2, ph: 1, pw: 1 }, 10);
    }

    #[test]
    fn loss_gemm_matches_oracle_1x1() {
        check_loss(ConvParams { b: 1, c: 3, hi: 8, wi: 8, n: 4, kh: 1, kw: 1, s: 2, ph: 0, pw: 0 }, 11);
    }

    #[test]
    fn loss_gemm_matches_oracle_inexact_division() {
        check_loss(ConvParams { b: 1, c: 2, hi: 10, wi: 10, n: 2, kh: 3, kw: 3, s: 2, ph: 0, pw: 0 }, 12);
    }

    #[test]
    fn loss_gemm_matches_oracle_stride3() {
        check_loss(ConvParams { b: 1, c: 2, hi: 11, wi: 8, n: 2, kh: 3, kw: 2, s: 3, ph: 1, pw: 0 }, 13);
    }

    #[test]
    fn grad_gemm_matches_oracle_stride2_pad1() {
        check_grad(ConvParams { b: 2, c: 2, hi: 9, wi: 9, n: 3, kh: 3, kw: 3, s: 2, ph: 1, pw: 1 }, 14);
    }

    #[test]
    fn grad_gemm_matches_oracle_1x1() {
        check_grad(ConvParams { b: 1, c: 3, hi: 8, wi: 8, n: 4, kh: 1, kw: 1, s: 2, ph: 0, pw: 0 }, 15);
    }

    #[test]
    fn grad_gemm_matches_oracle_inexact_division() {
        check_grad(ConvParams { b: 1, c: 2, hi: 10, wi: 10, n: 2, kh: 3, kw: 3, s: 2, ph: 0, pw: 0 }, 16);
    }

    #[test]
    fn grad_gemm_matches_oracle_stride4() {
        check_grad(ConvParams { b: 1, c: 1, hi: 12, wi: 12, n: 2, kh: 4, kw: 4, s: 4, ph: 0, pw: 0 }, 17);
    }

    #[test]
    fn loss_b_sparsity_is_high_for_stride2() {
        // §I claim: >= ~75 % zeros for stride >= 2.
        let p = ConvParams { b: 1, c: 2, hi: 16, wi: 16, n: 2, kh: 3, kw: 3, s: 2, ph: 1, pw: 1 };
        let mut rng = Rng::new(18);
        // Use all-nonzero dY so every zero in the matrix is structural.
        let dy = Tensor4::from_fn([p.b, p.n, p.ho(), p.wo()], |_, _, _, _| rng.range_f32(0.5, 1.0));
        let bm = lower_loss_b(&reorg::dilate_pad_loss(&dy, &p), &p);
        assert!(bm.sparsity() > 0.70, "sparsity {}", bm.sparsity());
    }

}
