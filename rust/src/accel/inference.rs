//! Inference-pass timing and whole-training-step costing.
//!
//! The paper evaluates only the two backward passes; a training
//! framework schedules fwd + loss + grad per layer. This module adds the
//! inference GEMM's cycle model (same array, same block-pass cost, the
//! 51-cycle stationary prologue, no reorganization in either mode — the
//! forward operand has padding zeros only) so the coordinator can report
//! full-step costs and the end-to-end example can attribute time.

use crate::accel::config::AccelConfig;
use crate::accel::tiling::{GemmShape, Tiling};
use crate::conv::ConvParams;
use crate::im2col::pipeline::Mode;
use crate::sim::addrgen::DIV_LATENCY;

/// Cycle/traffic summary of one inference pass (mode-independent: both
/// designs run inference identically).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FwdMetrics {
    /// Pure array cycles of the inference GEMMs.
    pub compute_cycles: f64,
    /// Address-generation prologues, summed over stripes.
    pub prologue_cycles: f64,
    /// Off-chip bytes: input + kernel + output, compact.
    pub dram_bytes: u64,
    /// Useful MACs of the forward convolution.
    pub macs: u64,
}

impl FwdMetrics {
    /// End-to-end runtime of the inference pass in cycles.
    pub fn total_cycles(&self) -> f64 {
        self.compute_cycles + self.prologue_cycles
    }
}

/// Inference GEMMs: `G` per-group `A_g[N/G x (C/G)*Kh*Kw] .
/// B_g[(C/G)*Kh*Kw x B*Ho*Wo]` (one GEMM for ungrouped layers).
pub fn simulate_fwd(p: &ConvParams, cfg: &AccelConfig) -> FwdMetrics {
    let shape = GemmShape { m: p.ng(), k: p.cg() * p.kh * p.kw, j: p.b * p.ho() * p.wo() };
    let til = Tiling::new(shape, cfg.array_dim);
    let groups = p.groups as f64;
    FwdMetrics {
        compute_cycles: til.compute_cycles() * groups,
        // Inference-style stationary addr-gen: 3 divider stages (Table
        // III's 51 cycles), once per stripe of every group's GEMM.
        prologue_cycles: (til.n_j * 3 * DIV_LATENCY) as f64 * groups,
        dram_bytes: ((p.input_elems() + p.kernel_elems() + p.output_elems()) * 4) as u64,
        macs: shape.macs() * p.groups as u64,
    }
}

/// Full training-step cost of one layer: fwd + loss + grad.
#[derive(Clone, Copy, Debug)]
pub struct StepCost {
    /// Inference (forward) cycles — identical in both im2col modes.
    pub fwd: f64,
    /// Loss-calculation (`dX`) cycles.
    pub loss: f64,
    /// Gradient-calculation (`dW`) cycles.
    pub grad: f64,
}

impl StepCost {
    /// Whole-step cycles: forward + both backward passes.
    pub fn total(&self) -> f64 {
        self.fwd + self.loss + self.grad
    }

    /// Fraction of the step spent in backpropagation.
    pub fn backward_fraction(&self) -> f64 {
        (self.loss + self.grad) / self.total()
    }
}

/// Whole-step cycles of one layer under `mode`.
pub fn training_step_cost(p: &ConvParams, mode: Mode, cfg: &AccelConfig) -> StepCost {
    let fwd = simulate_fwd(p, cfg).total_cycles();
    let l = crate::accel::timing::simulate_pass(crate::im2col::pipeline::Pass::Loss, mode, p, cfg);
    let g = crate::accel::timing::simulate_pass(crate::im2col::pipeline::Pass::Grad, mode, p, cfg);
    StepCost { fwd, loss: l.total_cycles(), grad: g.total_cycles() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::im2col::pipeline::Mode;

    #[test]
    fn fwd_cost_paper_layer1() {
        // (M,K,J) = (64, 27, 2*111*111): nK=2, nJ=1541, nM=4.
        let p = ConvParams::square(224, 3, 64, 3, 2, 0);
        let m = simulate_fwd(&p, &AccelConfig::default());
        assert!(m.compute_cycles > 0.0 && m.compute_cycles.is_finite());
        assert_eq!(m.macs, (64 * 27 * 2 * 111 * 111) as u64);
    }

    #[test]
    fn backward_dominates_training_step() {
        // Backprop is ~2/3 of a training step's conv work (dX + dW vs Y)
        // — the reason the paper's target matters.
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        let cost = training_step_cost(&p, Mode::BpIm2col, &AccelConfig::default());
        assert!(cost.backward_fraction() > 0.5, "{cost:?}");
    }

    #[test]
    fn step_speedup_between_pass_speedups() {
        // Whole-step speedup is diluted by the (mode-independent) fwd.
        let p = ConvParams::square(224, 3, 64, 3, 2, 0);
        let cfg = AccelConfig::default();
        let trad = training_step_cost(&p, Mode::Traditional, &cfg);
        let bp = training_step_cost(&p, Mode::BpIm2col, &cfg);
        let step_speedup = trad.total() / bp.total();
        assert!(step_speedup > 1.0);
        assert!(step_speedup < trad.grad / bp.grad * 1.01);
        assert_eq!(trad.fwd, bp.fwd);
    }
}
