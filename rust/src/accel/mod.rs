//! The TPU-like accelerator: composition of the [`crate::sim`] components
//! into the machine of the paper's Fig. 5, with two interchangeable
//! address-generation configurations (traditional im2col vs BP-im2col).
//!
//! Two execution levels:
//!
//! * [`timing`] — the analytic cycle/traffic engine used on full-size
//!   layers (Tables II–III, Figs. 6–8).
//! * [`plan`] — memoized layer plans: the full derivation of one
//!   `(layer, pass, mode, config)` lowering behind a hash-keyed cache,
//!   shared by the analytic model, the event machine and the
//!   coordinator (plan once, simulate many).
//! * [`functional`] — a datapath-faithful execution (address generation →
//!   NZ detection → compression → buffer fetch → crossbar → cycle-stepped
//!   systolic array) that produces *numerical* results, cross-checked
//!   against the functional oracle on small layers.
//! * [`strategy`] — the lowering-strategy family the plan builder is
//!   parametric over (explicit, implicit BP-im2col, EcoFlow-style
//!   scatter dataflows) plus the per-layer autotune selector
//!   (DESIGN.md §15).

pub mod config;
pub mod config_file;
pub mod functional;
pub mod inference;
pub mod metrics;
pub mod plan;
pub mod strategy;
pub mod tiling;
pub mod timing;

pub use config::AccelConfig;
pub use metrics::{LayerMetrics, PassMetrics};
pub use plan::{AutotuneChoice, LayerPlan, PlanCache, PlanCacheStats};
pub use strategy::{AutoObjective, LoweringSelect, LoweringStrategy};
pub use timing::{simulate_layer, simulate_pass};
