//! Memoized layer plans — plan a layer once, simulate it many times.
//!
//! Everything the analytic engine derives from `(ConvParams, Pass, Mode,
//! AccelConfig)` — the lowered [`GemmShape`], its [`Tiling`] onto the
//! array, the address-generation prologue latencies (Table III), the
//! sparsity closed forms, the dilated-mode window classification and the
//! resulting [`PassMetrics`] — is a pure function of those four inputs.
//! Since the sparse subsystem (DESIGN.md §14) the builder is also
//! **lowering-parametric**: the config's
//! [`crate::sparse::SparseLowering`] selects how *data* sparsity is
//! exploited (column combining packs the weight GEMM before tiling; a
//! SPOTS-style pipeline scales compute, buffer reads and traffic), with
//! the dense path — and the density-1.000 limit of both sparse paths —
//! bit-identical to the pre-sparse model.
//! The seed coordinator recomputed all of it from scratch for every
//! `BackpropJob`, even though a training run replays the *same* layer
//! geometries step after step and most CNNs repeat geometries across
//! stages (every ResNet/VGG block).
//!
//! [`LayerPlan`] captures the full derivation; [`PlanCache`] memoizes
//! plans behind a hash key so repeated layers are planned exactly once.
//! The cache is shared by the analytic model
//! ([`crate::accel::timing::simulate_pass`] is "build an uncached plan,
//! return its metrics"), the event machine
//! ([`crate::sim::machine::run_pass_planned`]) and the coordinator's
//! [`crate::coordinator::Scheduler`] / [`crate::coordinator::Fleet`],
//! which thread one `Arc<PlanCache>` through all their workers.
//!
//! Cached and cold paths are **bit-exact** by construction: the plan
//! stores the metrics the cold path would have produced, so a cache hit
//! returns the identical `PassMetrics` value (asserted over a seeded
//! geometry sweep in `tests/plan_fleet.rs`).

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Mutex, OnceLock};

use crate::accel::config::AccelConfig;
use crate::accel::metrics::PassMetrics;
use crate::accel::strategy::{LoweringSelect, LoweringStrategy};
use crate::accel::tiling::{GemmShape, Tiling};
use crate::accel::timing::{grad_window_crossings, grad_zero_windows, META_BYTES_PER_WINDOW};
use crate::conv::ConvParams;
use crate::im2col::pipeline::{Mode, Pass};
use crate::im2col::sparsity::{self, SparsityStats};
use crate::sim::addrgen::{prologue_cycles_for, Module};
use crate::sim::dram::DramTraffic;
use crate::sim::reorg_engine::reorg_cost;
use crate::sparse::column_combine::{self, PackingPlan};
use crate::sparse::{scale_u64, spots, SparseLowering};
use crate::trace::profile::{self, Phase};

/// The complete lowering of one `(layer, pass, mode)` onto one
/// accelerator configuration.
///
/// A plan owns every quantity the simulators need: shapes, tiling,
/// prologues, sparsity statistics, the dilated-mode window
/// classification, and the finished analytic [`PassMetrics`]. Building
/// one is the expensive step the [`PlanCache`] amortizes; consuming one
/// is a field read.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    /// Which backpropagation pass the plan lowers.
    pub pass: Pass,
    /// The **effective** lowering strategy the plan executes — the
    /// requested strategy normalized through
    /// [`LoweringStrategy::effective`] (EcoFlow variants degenerate to
    /// BP-im2col on layers without a zero-space, and on grouped
    /// layers). The [`PlanCache`] keys plans by the *requested*
    /// strategy.
    pub mode: Mode,
    /// The layer geometry the plan was built for.
    pub params: ConvParams,
    /// Per-group GEMM dimensions the array *executes*. Equal to the
    /// virtual lowered shape (paper Eq. 1) except under column
    /// combining on the loss pass, where `K` is packed
    /// ([`PackingPlan`]).
    pub shape: GemmShape,
    /// Tiling of the per-group (executed) GEMM onto the `T x T` array.
    pub tiling: Tiling,
    /// Column-combining packing of the weight-carrying GEMM, when the
    /// config's [`SparseLowering::ColumnCombine`] applies to this pass
    /// (loss only — the grad pass *produces* the weights).
    pub packing: Option<PackingPlan>,
    /// Stationary address-generation prologue per stripe (Table III),
    /// for this specific geometry.
    pub stationary_prologue: usize,
    /// Dynamic address-generation prologue per stripe (Table III).
    pub dynamic_prologue: usize,
    /// Sparsity of the stationary operand's virtual matrix.
    pub stat_sparsity: SparsityStats,
    /// Sparsity of the dynamic operand's virtual matrix (grad pass only;
    /// the loss pass streams the dense rotated kernel).
    pub dyn_sparsity: Option<SparsityStats>,
    /// Dilated-mode dynamic windows that are entirely structural zeros
    /// (the `sparse_skip` option elides them). 0 outside BP grad.
    pub zero_windows: usize,
    /// Dilated-mode windows whose lanes span a compact-row boundary and
    /// split the compressed fetch in two. 0 outside BP grad.
    pub window_crossings: usize,
    /// The finished analytic metrics of the pass — identical to what
    /// [`crate::accel::timing::simulate_pass`] returns for the same
    /// inputs.
    pub metrics: PassMetrics,
    /// Identity of the config the plan was built under (private: used to
    /// reject consuming a plan under a different configuration).
    cfg_key: CfgKey,
}

impl LayerPlan {
    /// Derive the full plan of one pass — the body of the analytic
    /// engine, recording its intermediates. This is the *only* place the
    /// pass model lives; `timing::simulate_pass` is a thin wrapper that
    /// builds an uncached plan and returns [`LayerPlan::metrics`].
    pub fn build(pass: Pass, mode: Mode, p: &ConvParams, cfg: &AccelConfig) -> Self {
        // Normalize first (DESIGN.md §15): the plan computes — and
        // records — the strategy whose closed forms this layer actually
        // executes, so "EcoFlow on a stride-1 undilated layer" is
        // *bit-identical* to BP-im2col rather than merely close.
        let mode = mode.effective(p);
        // Host-profiling sub-phases (DESIGN.md §16): the scoped timers
        // partition the build into shape/sparsity/tiling sections for
        // `repro profile`. They are opaque telemetry hooks — no wall
        // clock is named here, and nothing below reads them.
        let build_phase = profile::scope(Phase::PlanShape);
        let t = cfg.array_dim;
        let groups = p.groups;
        // Effective *data* density of this layer under this config: the
        // layer's own knob composed with the config-level density axis
        // (integer compose, exact identity when either side is 1000).
        let density = p.density.scaled_millis(cfg.density_millis);
        // Operand densities of this pass's GEMM (`A` dynamic, `B`
        // stationary): the loss pass streams the rotated kernel against
        // dY; in the grad pass both sides carry activation-class values
        // (dY against the input im2col).
        let (a_millis, b_millis) = match pass {
            Pass::Loss => (density.weight_millis, density.act_millis),
            Pass::Grad => (density.act_millis, density.act_millis),
        };
        // Per-group *virtual* (dense) GEMM; the layer runs `groups` of
        // them. Column combining packs the weight-carrying `K` of the
        // loss GEMM before tiling, so compute, blocks and reads shrink
        // structurally; the grad pass computes dW — weights are the
        // output there — and stays on the dense pipeline. All other
        // lowerings execute the virtual shape.
        let virtual_shape = GemmShape::from_pass(pass, p);
        let packing = match (cfg.lowering, pass) {
            (SparseLowering::ColumnCombine, Pass::Loss) => {
                Some(column_combine::pack_weight_gemm(virtual_shape, density.weight_millis))
            }
            _ => None,
        };
        let shape = packing.map_or(virtual_shape, |cc| cc.packed);
        let til = Tiling::new(shape, t);
        let mut compute_cycles = til.compute_cycles() * groups as f64;
        if let Some(cc) = &packing {
            // Operand-select MUX settle: one cycle per extra combined
            // slot per block pass (exactly 0.0 at pack == 1).
            compute_cycles += cc.select_cycles(til.block_passes()) * groups as f64;
        }

        // Dilated-mode window classification (BP grad only; both counts
        // are geometry-pure and group-independent).
        let (zero_windows, window_crossings) = match (mode, pass) {
            (Mode::BpIm2col, Pass::Grad) => {
                (grad_zero_windows(p, t), grad_window_crossings(p, t))
            }
            _ => (0, 0),
        };

        // Future-work sparse computation: skip the blocks whose dynamic
        // window is entirely zero-insertions.
        if cfg.sparse_skip && mode == Mode::BpIm2col && pass == Pass::Grad {
            compute_cycles *= 1.0 - zero_windows as f64 / til.n_k as f64;
        }

        // SPOTS-style pair skipping scales array occupancy by the
        // non-zero pair probability, floored by the streaming limit.
        // Gated on the lowering (not just the factor) so the dense path
        // stays structurally untouched; the factor itself is exactly
        // 1.0 when both operands are dense.
        let spots_factor = match cfg.lowering {
            SparseLowering::Spots => spots::compute_factor(a_millis, b_millis, t),
            SparseLowering::Dense | SparseLowering::ColumnCombine => 1.0,
        };
        if cfg.lowering == SparseLowering::Spots {
            compute_cycles *= spots_factor;
        }

        // ---- sparsity of the zero-spaced operand of this pass ----
        let build_phase = build_phase.next(Phase::PlanSparsity);
        let (stat_stats, dyn_stats) = match pass {
            Pass::Loss => (sparsity::loss_matrix_b(p), None),
            Pass::Grad => (sparsity::grad_matrix_b(p), Some(sparsity::grad_matrix_a(p))),
        };
        let pass_sparsity = match pass {
            Pass::Loss => stat_stats.sparsity(),
            Pass::Grad => dyn_stats.expect("grad has dynamic stats").sparsity(),
        };

        // ---- EcoFlow scatter compute (DESIGN.md §15) ----
        // Reached only on ungrouped layers with a zero-space (the
        // normalization above maps everything else to BP). The scatter
        // never materializes the zero-spaced operand, so compute scales
        // by its non-zero fraction on the pass the dataflow targets —
        // times a scatter-serialization factor: each streamed element
        // updates up to `Kh*Kw` accumulators, capped by the array edge.
        let scatter_factor = 1.0 + ((p.kh * p.kw).min(t) - 1) as f64 / t as f64;
        let eco_compute_factor = match (mode, pass) {
            // Output-stationary targets the transposed loss pass: the
            // stationary dYz zero-space vanishes.
            (Mode::EcoOutputStationary, Pass::Loss) => {
                (1.0 - stat_stats.sparsity()) * scatter_factor
            }
            // Input-stationary targets the dilated grad pass: the
            // dynamic dYd zero-space vanishes.
            (Mode::EcoInputStationary, Pass::Grad) => {
                (1.0 - dyn_stats.expect("grad has dynamic stats").sparsity()) * scatter_factor
            }
            // Each variant's off-pass pays the scatter with no skip —
            // dominated by construction, so the autotuner never picks
            // it there.
            (Mode::EcoOutputStationary, Pass::Grad)
            | (Mode::EcoInputStationary, Pass::Loss) => scatter_factor,
            // Exact identity for the paper's two modes.
            _ => 1.0,
        };
        compute_cycles *= eco_compute_factor;

        // ---- prologue: each addr-gen pipeline restarts per stationary
        //      stripe of every group's GEMM ----
        let build_phase = build_phase.next(Phase::PlanTiling);
        let stationary_prologue = prologue_cycles_for(mode, pass, Module::Stationary, p);
        let dynamic_prologue = prologue_cycles_for(mode, pass, Module::Dynamic, p);
        let prologue = (til.n_j * groups) as f64 * (stationary_prologue + dynamic_prologue) as f64;

        // ---- reorganization (explicit baseline only; whole dY, once
        //      per layer — every implicit strategy skips it) ----
        let (reorg_cycles, reorg_bytes, storage_overhead) = if mode.is_implicit() {
            (0.0, 0, 0)
        } else {
            let r = reorg_cost(pass, p, cfg.reorg_cycles_per_elem);
            (r.cycles, r.dram_bytes(), r.storage_bytes())
        };

        // ---- on-chip buffer reads toward the array (Fig. 8) ----
        let b_dense = til.buffer_b_dense_reads() * groups as u64;
        let a_dense = til.buffer_a_dense_reads() * groups as u64;
        let (buffer_a_reads, buffer_b_reads) = match (mode, pass) {
            // Baseline streams the zero-spaced operands densely.
            (Mode::Traditional, _) => (a_dense, b_dense),
            // Implicit loss: stationary matrix B reads only stored
            // pixels; dynamic matrix A (the kernel) is dense.
            (_, Pass::Loss) => {
                let nz_frac = 1.0 - stat_stats.sparsity();
                (a_dense, (b_dense as f64 * nz_frac) as u64)
            }
            // Implicit grad: dynamic matrix A reads only stored pixels;
            // stationary matrix B (input im2col) skips only padding zeros.
            (_, Pass::Grad) => {
                let a_nz = 1.0 - dyn_stats.expect("grad").sparsity();
                let b_nz = 1.0 - stat_stats.sparsity();
                ((a_dense as f64 * a_nz) as u64, (b_dense as f64 * b_nz) as u64)
            }
        };
        // Output-stationary scatter hands the reuse the stationary
        // dataflow had to the accumulators: the stationary operand is
        // re-fetched toward the array once per output-row tile.
        let buffer_b_reads = if mode == Mode::EcoOutputStationary {
            buffer_b_reads * til.n_m as u64
        } else {
            buffer_b_reads
        };
        // Under SPOTS the operands sit compressed on-chip, so only
        // non-zeros are fetched toward the array (floor scaling, exact
        // at density 1000). Column combining already shrank the reads
        // through the packed tiling above; Dense reads every value.
        let (buffer_a_reads, buffer_b_reads) = match cfg.lowering {
            SparseLowering::Spots => {
                (spots::scale_count(buffer_a_reads, a_millis), spots::scale_count(buffer_b_reads, b_millis))
            }
            SparseLowering::Dense | SparseLowering::ColumnCombine => {
                (buffer_a_reads, buffer_b_reads)
            }
        };

        // ---- off-chip traffic (Fig. 7) ----
        // Unique underlying operand data over all groups, fetched once
        // per pass into the double-buffered on-chip buffers (working-set
        // rule, DESIGN.md §5).
        let (a_unique_trad, a_unique_bp) = match pass {
            // Loss: dynamic matrix is the dense rotated kernel (all groups).
            Pass::Loss => {
                let e = p.kernel_elems();
                (e, e)
            }
            // Grad: dynamic matrix is the zero-inserted dY (virtual, all
            // groups = N rows) vs the compact dY (BP).
            Pass::Grad => (groups * shape.m * shape.k, p.output_elems()),
        };
        debug_assert!(
            shape.dynamic_panel_elems(t) <= cfg.buf_a_half,
            "dynamic panel must fit one buffer-A half"
        );

        let (b_unique_trad, b_unique_bp) = match pass {
            // Loss: stationary source is the zero-spaced dYz vs compact dY.
            Pass::Loss => (p.b * p.n * p.ho3() * p.wo3(), p.output_elems()),
            // Grad: stationary source is the padded input vs compact
            // input (padding zeros are never stored off-chip in either
            // mode, but the baseline materializes Xpad during its
            // explicit pipeline).
            Pass::Grad => (
                p.b * p.c * (p.hi + 2 * p.ph) * (p.wi + 2 * p.pw),
                p.input_elems(),
            ),
        };

        let out_bytes = (groups * shape.m * shape.j * 4) as u64;
        let traffic = if mode.is_implicit() {
            DramTraffic {
                a_bytes: (a_unique_bp * 4) as u64,
                b_bytes: (b_unique_bp * 4) as u64,
                out_bytes,
                reorg_bytes: 0,
                // Compressed base addresses ride the command bus as read
                // requests and the masks never leave the chip — they are
                // not data traffic (Fig. 7 measures data transmission).
                meta_bytes: 0,
            }
        } else {
            DramTraffic {
                a_bytes: (a_unique_trad * 4) as u64,
                b_bytes: (b_unique_trad * 4) as u64,
                out_bytes,
                reorg_bytes,
                meta_bytes: 0,
            }
        };
        // Lowering-specific traffic shape: compressed values plus
        // sideband metadata. Integer scaling keeps every term exactly
        // its dense value at density 1000, and the Dense arm passes the
        // struct through untouched.
        let traffic = match cfg.lowering {
            SparseLowering::Dense => traffic,
            SparseLowering::ColumnCombine => match &packing {
                // Packed weights ship pruned (values scaled by weight
                // density) plus the per-slot select indices.
                Some(cc) => DramTraffic {
                    a_bytes: scale_u64(traffic.a_bytes, density.weight_millis),
                    meta_bytes: traffic.meta_bytes + cc.index_bytes() * groups as u64,
                    ..traffic
                },
                // Grad pass: weights are the output — dense pipeline.
                None => traffic,
            },
            SparseLowering::Spots => DramTraffic {
                a_bytes: spots::compressed_bytes(traffic.a_bytes, a_millis),
                b_bytes: spots::compressed_bytes(traffic.b_bytes, b_millis),
                meta_bytes: traffic.meta_bytes
                    + spots::bitmap_bytes(traffic.a_bytes / 4, a_millis)
                    + spots::bitmap_bytes(traffic.b_bytes / 4, b_millis),
                ..traffic
            },
        };
        // EcoFlow traffic shape, composed after the data-sparsity
        // scaling. Output-stationary re-fetches the stationary operand
        // per output-row tile; input-stationary round-trips partial
        // sums through the accumulator per K tile (`n_k` writes plus
        // `n_k - 1` read-backs, the last write is final).
        let traffic = match mode {
            Mode::EcoOutputStationary => {
                DramTraffic { b_bytes: traffic.b_bytes * til.n_m as u64, ..traffic }
            }
            Mode::EcoInputStationary => DramTraffic {
                out_bytes: traffic.out_bytes * (2 * til.n_k as u64 - 1),
                ..traffic
            },
            Mode::Traditional | Mode::BpIm2col => traffic,
        };

        // ---- additional storage beyond the compact tensors ----
        // Baseline: the zero-spaced DRAM copy. BP: masks/base addresses
        // are produced on the fly and consumed streaming; the only
        // standing state is the double-buffered in-flight window queue of
        // each address-generation module (depth 64 windows here).
        const WINDOW_QUEUE_DEPTH: u64 = 64;
        let mut storage_overhead_bytes = match mode {
            Mode::Traditional => storage_overhead,
            Mode::BpIm2col => 2 * 2 * WINDOW_QUEUE_DEPTH * META_BYTES_PER_WINDOW,
            // The scatter dataflows keep no window queue (no masks) but
            // own a double-buffered FP32 accumulator: an output stripe
            // (OS) or one array tile of partial sums (IS).
            Mode::EcoOutputStationary => (2 * 4 * shape.m * t) as u64,
            Mode::EcoInputStationary => (2 * 4 * t * t) as u64,
        };
        if let Some(cc) = &packing {
            // Select indices stand in buffer A alongside the packed
            // weights for the whole pass (0 when nothing is packed).
            storage_overhead_bytes += cc.index_bytes() * groups as u64;
        }

        // ---- extra fetch cycles from split compressed runs ----
        let extra_fetch_cycles = match (mode, pass) {
            (Mode::BpIm2col, Pass::Grad) => {
                (window_crossings * til.n_j * groups) as f64 * shape.m as f64 / t as f64
            }
            _ => 0.0,
        };

        // ---- DRAM fill stalls per stripe ----
        let stripes = (til.n_j * groups) as f64;
        let fill_elems_per_stripe =
            (traffic.a_bytes + traffic.b_bytes + traffic.meta_bytes) as f64 / 4.0 / stripes;
        let fill_cycles = cfg.dram.transfer_cycles(fill_elems_per_stripe.ceil() as usize);
        // The skipping core drains a stripe faster, so fill stalls can
        // grow under SPOTS — the factor is exactly 1.0 otherwise.
        let stripe_compute = match cfg.lowering {
            SparseLowering::Spots => til.stripe_compute_cycles() * spots_factor,
            SparseLowering::Dense | SparseLowering::ColumnCombine => til.stripe_compute_cycles(),
        };
        // The scatter-scaled core drains a stripe at the same scaled
        // rate (exact identity at factor 1.0 — the paper's two modes).
        let stripe_compute = stripe_compute * eco_compute_factor;
        let stall_cycles = stripes * (fill_cycles - stripe_compute).max(0.0);
        drop(build_phase);

        let metrics = PassMetrics {
            pass,
            mode,
            compute_cycles,
            reorg_cycles,
            prologue_cycles: prologue,
            stall_cycles,
            extra_fetch_cycles,
            traffic,
            buffer_a_reads,
            buffer_b_reads,
            storage_overhead_bytes,
            sparsity: pass_sparsity,
            // Useful MACs of the *virtual* GEMM — invariant across
            // lowerings (packing/skipping change cycles, not the math).
            macs: virtual_shape.macs() * groups as u64,
        };

        Self {
            pass,
            mode,
            params: *p,
            shape,
            tiling: til,
            packing,
            stationary_prologue,
            dynamic_prologue,
            stat_sparsity: stat_stats,
            dyn_sparsity: dyn_stats,
            zero_windows,
            window_crossings,
            metrics,
            cfg_key: CfgKey::of(cfg),
        }
    }

    /// True when the plan was built under a config with identical
    /// simulation-relevant fields (every field bit-identical). Consumers
    /// that take a plan *and* a config ([`crate::sim::machine::run_pass_planned`])
    /// use this to reject mixed configurations.
    pub fn matches_config(&self, cfg: &AccelConfig) -> bool {
        self.cfg_key == CfgKey::of(cfg)
    }

    /// Combined per-stripe address-generation prologue, in cycles.
    pub fn prologue_per_stripe(&self) -> f64 {
        (self.stationary_prologue + self.dynamic_prologue) as f64
    }

    /// Stationary stripes of the whole layer (all groups).
    pub fn stripes(&self) -> usize {
        self.tiling.n_j * self.params.groups
    }
}

/// Hashable identity of an [`AccelConfig`] (float fields keyed by their
/// bit patterns: two configs plan identically iff every field is
/// bit-identical). Crate-visible: the design-space engine dedups its
/// candidates by the same identity ([`crate::dse::search`]), so there
/// is exactly one definition of "the same config".
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) struct CfgKey {
    array_dim: usize,
    buf_a_half: usize,
    buf_b_half: usize,
    elems_per_cycle_bits: u64,
    burst_overhead_bits: u64,
    burst_len: usize,
    reorg_cycles_per_elem_bits: u64,
    sparse_skip: bool,
    lowering: SparseLowering,
    density_millis: usize,
    strategy: LoweringSelect,
    objective: crate::accel::strategy::AutoObjective,
}

impl CfgKey {
    pub(crate) fn of(cfg: &AccelConfig) -> Self {
        // Exhaustive destructuring (no `..`): adding a field to
        // AccelConfig or DramModel without extending this key is a
        // compile error, not a silent cache collision.
        let AccelConfig {
            array_dim,
            dram,
            buf_a_half,
            buf_b_half,
            reorg_cycles_per_elem,
            sparse_skip,
            lowering,
            density_millis,
            strategy,
            objective,
        } = *cfg;
        let crate::sim::dram::DramModel { elems_per_cycle, burst_overhead, burst_len } = dram;
        Self {
            array_dim,
            buf_a_half,
            buf_b_half,
            elems_per_cycle_bits: elems_per_cycle.to_bits(),
            burst_overhead_bits: burst_overhead.to_bits(),
            burst_len,
            reorg_cycles_per_elem_bits: reorg_cycles_per_elem.to_bits(),
            sparse_skip,
            lowering,
            density_millis,
            strategy,
            objective,
        }
    }
}

/// Full memo key: layer geometry + pass + mode + accelerator config.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    params: ConvParams,
    pass: Pass,
    mode: Mode,
    cfg: CfgKey,
}

/// The autotuner's verdict for one `(layer, pass, config)`: every
/// candidate strategy's scalar cost plus the winner's metrics
/// ([`PlanCache::autotune`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutotuneChoice {
    /// The min-cost strategy; ties resolve to the earliest entry of
    /// [`LoweringStrategy::STRATEGIES`].
    pub chosen: LoweringStrategy,
    /// Metrics of the chosen strategy's plan.
    pub metrics: PassMetrics,
    /// Cost of every candidate under the config's
    /// [`crate::accel::strategy::AutoObjective`], indexed like
    /// [`LoweringStrategy::STRATEGIES`].
    pub costs: [f64; LoweringStrategy::STRATEGIES.len()],
}

impl AutotuneChoice {
    /// Cost of the chosen strategy (equals `min(costs)`).
    pub fn chosen_cost(&self) -> f64 {
        self.costs[self.chosen.code() as usize]
    }
}

/// Hit/miss counters of a [`PlanCache`] (the planning-amortization
/// numbers `repro fleet`, `/metrics` and `benches/simspeed.rs` report).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups answered from the memo table.
    pub hits: u64,
    /// Lookups that had to build a fresh plan.
    pub misses: u64,
    /// Distinct plans currently stored.
    pub entries: usize,
    /// Plan builds per *requested* lowering strategy, indexed by
    /// [`LoweringStrategy::code`] (trad/bp/eco-os/eco-is). Counted at
    /// miss-classification time under the table lock, so the split is
    /// as deterministic as the hit/miss split itself; summed over
    /// strategies it equals `misses`.
    pub builds: [u64; LoweringStrategy::STRATEGIES.len()],
}

impl PlanCacheStats {
    /// Total lookups (`hits + misses`) — one per `plan`/`metrics` call.
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache, in `[0, 1]`.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    /// Builds summed over every strategy (equals `misses`).
    pub fn builds_total(&self) -> u64 {
        self.builds.iter().sum()
    }

    /// One-line human summary:
    /// `plan cache: 14 distinct plans, 14 hits / 14 misses over 28 lookups`.
    ///
    /// Every counter in it is deterministic — hit/miss classification
    /// happens under the table lock, so for a fixed request set the
    /// split is identical run to run, however many workers race (the
    /// historical lookups-only workaround is gone; asserted over a
    /// seeded device sweep in `tests/plan_fleet.rs`).
    pub fn summary(&self) -> String {
        format!(
            "plan cache: {} distinct plans, {} hits / {} misses over {} lookups",
            self.entries,
            self.hits,
            self.misses,
            self.lookups()
        )
    }

    /// One-line per-strategy build split, label order =
    /// [`LoweringStrategy::STRATEGIES`]:
    /// `plan builds by strategy: trad=3 bp=8 eco-os=2 eco-is=1`.
    pub fn builds_summary(&self) -> String {
        let mut out = String::from("plan builds by strategy:");
        for i in 0..LoweringStrategy::STRATEGIES.len() {
            out.push_str(&format!(
                " {}={}",
                LoweringStrategy::STRATEGIES[i].name(),
                self.builds[i]
            ));
        }
        out
    }
}

/// Thread-safe memo table of [`LayerPlan`]s, keyed by
/// `(ConvParams, Pass, Mode, AccelConfig)`.
///
/// Share one cache (behind an `Arc`) across every consumer that replays
/// layer geometries — scheduler workers, fleet devices, sweep loops —
/// and repeated layers are planned once.
///
/// # Example
///
/// ```
/// use std::sync::Arc;
/// use bp_im2col::accel::plan::PlanCache;
/// use bp_im2col::accel::{simulate_pass, AccelConfig};
/// use bp_im2col::im2col::pipeline::{Mode, Pass};
/// use bp_im2col::ConvParams;
///
/// let cache = Arc::new(PlanCache::new());
/// let cfg = AccelConfig::default();
/// let p = ConvParams::square(56, 128, 128, 3, 2, 1);
///
/// let first = cache.metrics(Pass::Grad, Mode::BpIm2col, &p, &cfg); // miss: plans
/// let second = cache.metrics(Pass::Grad, Mode::BpIm2col, &p, &cfg); // hit: memoized
/// assert_eq!(first, second);
/// // Bit-exact with the uncached analytic engine.
/// assert_eq!(first, simulate_pass(Pass::Grad, Mode::BpIm2col, &p, &cfg));
/// let stats = cache.stats();
/// assert_eq!((stats.hits, stats.misses, stats.entries), (1, 1, 1));
/// ```
#[derive(Default)]
pub struct PlanCache {
    inner: Mutex<PlanCacheInner>,
}

/// Table and counters behind one lock: hit/miss classification and the
/// slot insert are a single critical section, so the split cannot race.
/// (The seed kept the counters in separate atomics bumped *outside* the
/// table lock; two workers racing the same key then both counted a miss
/// and the reported split varied run to run.)
#[derive(Default)]
struct PlanCacheInner {
    /// One build slot per key. The slot — not the table — synchronizes
    /// the build itself, so distinct keys still plan in parallel and a
    /// key is built exactly once ([`OnceLock`] runs one initializer and
    /// blocks latecomers until it finishes).
    plans: HashMap<PlanKey, Arc<OnceLock<Arc<LayerPlan>>>>,
    hits: u64,
    misses: u64,
    /// Builds per requested strategy ([`LoweringStrategy::code`] index),
    /// bumped with the miss classification under the same lock.
    builds: [u64; LoweringStrategy::STRATEGIES.len()],
}

impl PlanCache {
    /// Hard bound on memoized plans. Far above any honest workload (the
    /// full extended sweep is dozens of plans), it exists so an
    /// adversarial stream of distinct geometries (e.g. through
    /// `repro serve`) cannot grow the table without limit: past the
    /// bound, lookups still build correct plans, they just stop
    /// memoizing.
    pub const MAX_ENTRIES: usize = 1 << 16;

    /// Empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// The memoized plan for `(pass, mode, p, cfg)`, building it on first
    /// use.
    ///
    /// Planning happens *outside* the table lock (inside the key's own
    /// [`OnceLock`]), so concurrent workers never serialize on a build of
    /// a different key, and every key is built **exactly once** — the
    /// first looker-up of a key counts the one miss and every other
    /// caller (even one that arrives mid-build and blocks on the slot)
    /// counts a hit. For a fixed request set the hit/miss split is
    /// therefore deterministic: `misses == entries`,
    /// `hits == lookups - entries`, regardless of thread interleaving
    /// (below [`PlanCache::MAX_ENTRIES`] and absent build panics; a
    /// panicking build removes its slot again, so no phantom entry
    /// lingers and the panic reproduces on retry instead of
    /// masquerading as a hit).
    pub fn plan(&self, pass: Pass, mode: Mode, p: &ConvParams, cfg: &AccelConfig) -> Arc<LayerPlan> {
        let key = PlanKey { params: *p, pass, mode, cfg: CfgKey::of(cfg) };
        let slot = {
            let mut guard = self.inner.lock().expect("plan cache poisoned");
            let inner = &mut *guard;
            if let Some(existing) = inner.plans.get(&key) {
                inner.hits += 1;
                Arc::clone(existing)
            } else {
                inner.misses += 1;
                inner.builds[usize::from(mode.code())] += 1;
                if inner.plans.len() >= Self::MAX_ENTRIES {
                    // Table full: plan without memoizing (outside the
                    // lock).
                    drop(guard);
                    return Arc::new(profile::time(Phase::PlanBuild, || {
                        LayerPlan::build(pass, mode, p, cfg)
                    }));
                }
                match inner.plans.entry(key) {
                    Entry::Occupied(e) => Arc::clone(e.get()),
                    Entry::Vacant(v) => Arc::clone(v.insert(Arc::new(OnceLock::new()))),
                }
            }
        };
        // Build outside the table lock. If the build panics, evict the
        // still-empty slot so the table never carries a phantom entry
        // (and the next lookup of the key honestly re-misses).
        match panic::catch_unwind(AssertUnwindSafe(|| {
            Arc::clone(slot.get_or_init(|| {
                Arc::new(profile::time(Phase::PlanBuild, || LayerPlan::build(pass, mode, p, cfg)))
            }))
        })) {
            Ok(plan) => plan,
            Err(payload) => {
                if slot.get().is_none() {
                    if let Ok(mut inner) = self.inner.lock() {
                        // Evict only *this* slot: by the time we take
                        // the lock, another thread may have evicted it
                        // already and re-missed a fresh slot for the
                        // key — that one is not ours to remove.
                        let ours = inner
                            .plans
                            .get(&key)
                            .is_some_and(|s| Arc::ptr_eq(s, &slot) && s.get().is_none());
                        if ours {
                            inner.plans.remove(&key);
                        }
                    }
                }
                panic::resume_unwind(payload)
            }
        }
    }

    /// The analytic [`PassMetrics`] of `(pass, mode, p, cfg)` through the
    /// cache — bit-exact with
    /// [`crate::accel::timing::simulate_pass`].
    pub fn metrics(&self, pass: Pass, mode: Mode, p: &ConvParams, cfg: &AccelConfig) -> PassMetrics {
        self.plan(pass, mode, p, cfg).metrics
    }

    /// Score every [`LoweringStrategy`] for `(pass, p, cfg)` under the
    /// config's objective and pick the minimum — the per-layer
    /// autotuner of DESIGN.md §15.
    ///
    /// Every candidate plan goes through the cache (one lookup per
    /// strategy, keyed by the *requested* strategy): a cold autotune
    /// over `N` distinct `(layer, pass)` keys misses exactly `N x S`
    /// times and a warm one misses zero times
    /// (`tests/autotune.rs::autotune_cache_misses_are_exactly_n_by_s`).
    /// Selection is deterministic: costs are pure functions of the
    /// inputs and the strict `<` comparison resolves ties to the
    /// earliest entry of [`LoweringStrategy::STRATEGIES`], independent
    /// of thread count, device count and frontend.
    pub fn autotune(&self, pass: Pass, p: &ConvParams, cfg: &AccelConfig) -> AutotuneChoice {
        // Host-profiling: one pricing pass over the candidate loop
        // (cached candidate plans make a warm pricing cost ~0).
        let _pricing = profile::scope(Phase::PlanPricing);
        let mut costs = [0.0f64; LoweringStrategy::STRATEGIES.len()];
        let mut chosen = LoweringStrategy::STRATEGIES[0];
        let mut best = f64::INFINITY;
        let mut metrics = None;
        for (i, s) in LoweringStrategy::STRATEGIES.iter().enumerate() {
            let m = self.metrics(pass, *s, p, cfg);
            let cost = cfg.objective.cost(&m);
            costs[i] = cost;
            if cost < best {
                best = cost;
                chosen = *s;
                metrics = Some(m);
            }
        }
        AutotuneChoice { chosen, metrics: metrics.expect("STRATEGIES is non-empty"), costs }
    }

    /// The strategy the config's [`LoweringSelect`] resolves to for
    /// `(pass, p)`: the fixed strategy, or the autotuner's pick. Pure
    /// in its inputs — schedulers and fleets of any width resolve the
    /// same choice bit-identically.
    pub fn strategy_for(&self, pass: Pass, p: &ConvParams, cfg: &AccelConfig) -> LoweringStrategy {
        match cfg.strategy {
            LoweringSelect::Fixed(s) => s,
            LoweringSelect::Auto => self.autotune(pass, p, cfg).chosen,
        }
    }

    /// [`PlanCache::metrics`] under the config's own strategy selection
    /// ([`AccelConfig::strategy`]) instead of a positional mode.
    pub fn metrics_select(&self, pass: Pass, p: &ConvParams, cfg: &AccelConfig) -> PassMetrics {
        match cfg.strategy {
            LoweringSelect::Fixed(s) => self.metrics(pass, s, p, cfg),
            LoweringSelect::Auto => self.autotune(pass, p, cfg).metrics,
        }
    }

    /// Current hit/miss/entry counters, read as one consistent snapshot
    /// (all three under the same lock that classifies lookups).
    pub fn stats(&self) -> PlanCacheStats {
        let inner = self.inner.lock().expect("plan cache poisoned");
        PlanCacheStats {
            hits: inner.hits,
            misses: inner.misses,
            entries: inner.plans.len(),
            builds: inner.builds,
        }
    }

    /// Drop every memoized plan and zero the counters.
    pub fn clear(&self) {
        let mut inner = self.inner.lock().expect("plan cache poisoned");
        inner.plans.clear();
        inner.hits = 0;
        inner.misses = 0;
        inner.builds = [0; LoweringStrategy::STRATEGIES.len()];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::timing::simulate_pass;

    fn cfg() -> AccelConfig {
        AccelConfig::default()
    }

    #[test]
    fn plan_metrics_equal_cold_simulate_pass() {
        for p in [
            ConvParams::square(112, 64, 64, 3, 2, 1),
            ConvParams::square(56, 256, 512, 1, 2, 0),
            ConvParams::square(28, 256, 256, 3, 1, 2).with_dilation(2, 2),
            ConvParams::square(56, 128, 128, 3, 2, 1).with_groups(32),
        ] {
            for pass in Pass::ALL {
                for mode in Mode::ALL {
                    let plan = LayerPlan::build(pass, mode, &p, &cfg());
                    assert_eq!(plan.metrics, simulate_pass(pass, mode, &p, &cfg()), "{} {pass:?} {mode:?}", p.id());
                }
            }
        }
    }

    #[test]
    fn cache_hits_return_the_same_plan() {
        let cache = PlanCache::new();
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        let a = cache.plan(Pass::Loss, Mode::BpIm2col, &p, &cfg());
        let b = cache.plan(Pass::Loss, Mode::BpIm2col, &p, &cfg());
        assert!(Arc::ptr_eq(&a, &b), "hit must return the memoized Arc");
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
        assert!((st.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_inputs_get_distinct_entries() {
        let cache = PlanCache::new();
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        cache.metrics(Pass::Loss, Mode::BpIm2col, &p, &cfg());
        cache.metrics(Pass::Grad, Mode::BpIm2col, &p, &cfg());
        cache.metrics(Pass::Loss, Mode::Traditional, &p, &cfg());
        // Different config (bandwidth) is a different key.
        cache.metrics(Pass::Loss, Mode::BpIm2col, &p, &AccelConfig::bandwidth_limited(1.0));
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (0, 4, 4));
    }

    #[test]
    fn builds_split_by_requested_strategy() {
        let cache = PlanCache::new();
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        cache.metrics(Pass::Loss, Mode::Traditional, &p, &cfg());
        cache.metrics(Pass::Loss, Mode::BpIm2col, &p, &cfg());
        cache.metrics(Pass::Grad, Mode::BpIm2col, &p, &cfg());
        cache.metrics(Pass::Loss, Mode::BpIm2col, &p, &cfg()); // hit: no build
        cache.metrics(Pass::Loss, Mode::EcoOutputStationary, &p, &cfg());
        let st = cache.stats();
        // STRATEGIES order: trad / bp / eco-os / eco-is.
        assert_eq!(st.builds, [1, 2, 1, 0]);
        assert_eq!(st.builds_total(), st.misses);
        assert_eq!(st.builds_summary(), "plan builds by strategy: trad=1 bp=2 eco-os=1 eco-is=0");
        cache.clear();
        assert_eq!(cache.stats().builds, [0; 4]);
    }

    #[test]
    fn clear_resets_everything() {
        let cache = PlanCache::new();
        let p = ConvParams::square(56, 256, 512, 1, 2, 0);
        cache.metrics(Pass::Loss, Mode::BpIm2col, &p, &cfg());
        cache.metrics(Pass::Loss, Mode::BpIm2col, &p, &cfg());
        cache.clear();
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (0, 0, 0));
    }

    #[test]
    fn shared_cache_is_thread_safe_and_exact() {
        use std::thread;
        let cache = Arc::new(PlanCache::new());
        let p = ConvParams::square(28, 244, 244, 3, 2, 1);
        let cold = simulate_pass(Pass::Grad, Mode::BpIm2col, &p, &cfg());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = Arc::clone(&cache);
                thread::spawn(move || c.metrics(Pass::Grad, Mode::BpIm2col, &p, &cfg()))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), cold);
        }
        // Exactly one entry no matter how the race resolved — and the
        // hit/miss split is exact too: the first looker-up counts the
        // one miss, the other three count hits (even those that blocked
        // on the in-flight build).
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.entries), (3, 1, 1));
    }

    #[test]
    fn hit_miss_split_is_deterministic_under_contention() {
        use std::thread;
        // Many threads, many keys, replayed lookups: for a fixed lookup
        // multiset the counters must come out identical on every run.
        let geoms: Vec<ConvParams> = (0..6)
            .map(|i| ConvParams::square(16 + 8 * i, 8, 8, 3, 2, 1))
            .collect();
        let run = || {
            let cache = Arc::new(PlanCache::new());
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let c = Arc::clone(&cache);
                    let gs = geoms.clone();
                    thread::spawn(move || {
                        for p in &gs {
                            for pass in Pass::ALL {
                                c.metrics(pass, Mode::BpIm2col, p, &cfg());
                            }
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            cache.stats()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "stats must not depend on interleaving");
        assert_eq!(a.entries, geoms.len() * 2, "one entry per (geometry, pass)");
        assert_eq!(a.misses, a.entries as u64, "one miss per distinct key");
        assert_eq!(a.lookups(), (8 * geoms.len() * 2) as u64);
    }

    /// Overflow checks make the bad-geometry build panic; in release the
    /// arithmetic wraps instead, so the eviction path is exercised under
    /// the test profile only.
    #[test]
    #[cfg(debug_assertions)]
    fn panicking_build_leaves_no_phantom_entry() {
        let cache = PlanCache::new();
        // Kernel larger than the (unpadded) input: output-dim
        // subtraction underflows inside the build. `validate()` rejects
        // this geometry — the cache itself must still stay clean when
        // called below the validation layer.
        let bad = ConvParams::square(4, 1, 1, 9, 1, 0);
        for attempt in 0..2 {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                cache.plan(Pass::Loss, Mode::BpIm2col, &bad, &cfg())
            }));
            assert!(result.is_err(), "attempt {attempt} must panic");
        }
        let st = cache.stats();
        assert_eq!(st.entries, 0, "no phantom entry may linger: {st:?}");
        assert_eq!(st.misses, 2, "each failed attempt honestly re-misses: {st:?}");
        assert_eq!(st.hits, 0, "{st:?}");
        // And the cache still works for good geometries afterwards.
        let good = ConvParams::square(56, 64, 64, 3, 2, 1);
        cache.metrics(Pass::Loss, Mode::BpIm2col, &good, &cfg());
        assert_eq!(cache.stats().entries, 1);
    }

    #[test]
    fn plan_records_geometry_intermediates() {
        let p = ConvParams::square(56, 256, 512, 1, 2, 0);
        let plan = LayerPlan::build(Pass::Grad, Mode::BpIm2col, &p, &cfg());
        assert_eq!(plan.shape, GemmShape::from_pass(Pass::Grad, &p));
        assert_eq!(plan.tiling, Tiling::new(plan.shape, 16));
        assert!(plan.packing.is_none(), "dense lowering never packs");
        // Table III: BP grad = 68 dynamic + 51 stationary.
        assert_eq!((plan.dynamic_prologue, plan.stationary_prologue), (68, 51));
        assert!(plan.dyn_sparsity.is_some());
        assert!(plan.zero_windows > 0, "stride-2 grad has all-zero windows");
        assert_eq!(plan.stripes(), plan.tiling.n_j);
    }

    #[test]
    fn dense_lowering_ignores_density() {
        // The dense array streams zeros like any other value: a pruned
        // layer under SparseLowering::Dense costs exactly what the
        // unpruned layer costs (the comparison baseline of
        // `repro sparse`).
        let dense = ConvParams::square(56, 128, 128, 3, 2, 1);
        let pruned = dense.with_density(250, 500);
        for pass in Pass::ALL {
            for mode in Mode::ALL {
                assert_eq!(
                    LayerPlan::build(pass, mode, &pruned, &cfg()).metrics,
                    LayerPlan::build(pass, mode, &dense, &cfg()).metrics,
                    "{pass:?} {mode:?}"
                );
            }
        }
    }

    #[test]
    fn dense_limit_is_bitwise_identical_under_every_lowering() {
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        for lowering in SparseLowering::ALL {
            let c = AccelConfig { lowering, ..cfg() };
            for pass in Pass::ALL {
                for mode in Mode::ALL {
                    assert_eq!(
                        LayerPlan::build(pass, mode, &p, &c).metrics,
                        LayerPlan::build(pass, mode, &p, &cfg()).metrics,
                        "{lowering:?} {pass:?} {mode:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn column_combining_packs_the_loss_gemm_only() {
        let p = ConvParams::square(56, 128, 128, 3, 2, 1).with_density(250, 1000);
        let c = AccelConfig { lowering: SparseLowering::ColumnCombine, ..cfg() };
        let plan = LayerPlan::build(Pass::Loss, Mode::BpIm2col, &p, &c);
        let dense = LayerPlan::build(Pass::Loss, Mode::BpIm2col, &p, &cfg());
        let packing = plan.packing.expect("loss pass under CC packs");
        assert_eq!(packing.pack, 4);
        assert_eq!(plan.shape.k, (dense.shape.k + 3) / 4, "K packed by the factor");
        assert!(plan.metrics.compute_cycles < dense.metrics.compute_cycles);
        assert!(plan.metrics.traffic.a_bytes < dense.metrics.traffic.a_bytes);
        assert!(plan.metrics.traffic.meta_bytes > 0, "select indices ride the meta bus");
        assert!(plan.metrics.storage_overhead_bytes > dense.metrics.storage_overhead_bytes);
        assert_eq!(plan.metrics.macs, dense.metrics.macs, "useful MACs are lowering-invariant");
        // Grad pass computes dW — weights are the output, nothing to
        // combine: bit-identical to the dense pipeline.
        let grad = LayerPlan::build(Pass::Grad, Mode::BpIm2col, &p, &c);
        assert!(grad.packing.is_none());
        assert_eq!(grad.metrics, LayerPlan::build(Pass::Grad, Mode::BpIm2col, &p, &cfg()).metrics);
    }

    #[test]
    fn spots_scales_compute_reads_and_traffic() {
        let p = ConvParams::square(56, 128, 128, 3, 2, 1).with_density(500, 500);
        let c = AccelConfig { lowering: SparseLowering::Spots, ..cfg() };
        for pass in Pass::ALL {
            let sp = LayerPlan::build(pass, Mode::BpIm2col, &p, &c);
            let dn = LayerPlan::build(pass, Mode::BpIm2col, &p, &cfg());
            assert!(sp.metrics.compute_cycles < dn.metrics.compute_cycles, "{pass:?}");
            assert!(sp.metrics.buffer_a_reads < dn.metrics.buffer_a_reads, "{pass:?}");
            assert!(sp.metrics.buffer_b_reads < dn.metrics.buffer_b_reads, "{pass:?}");
            assert!(sp.metrics.traffic.a_bytes < dn.metrics.traffic.a_bytes, "{pass:?}");
            assert!(sp.metrics.traffic.meta_bytes > 0, "bitmaps ride the meta bus: {pass:?}");
            assert_eq!(sp.metrics.macs, dn.metrics.macs, "{pass:?}");
        }
    }

    #[test]
    fn eco_dataflows_win_their_target_pass_on_strided_layers() {
        // The whole point of the EcoFlow variants: on zero-spaced
        // layers, OS beats BP on the transposed loss pass and IS beats
        // BP on the dilated grad pass — while each off-pass is
        // dominated (never the autotune pick).
        for p in [
            ConvParams::square(112, 64, 64, 3, 2, 1),
            ConvParams::square(56, 256, 512, 1, 2, 0),
            ConvParams::square(28, 244, 244, 3, 2, 1),
        ] {
            let bp_loss = LayerPlan::build(Pass::Loss, Mode::BpIm2col, &p, &cfg()).metrics;
            let os_loss = LayerPlan::build(Pass::Loss, Mode::EcoOutputStationary, &p, &cfg()).metrics;
            assert!(
                os_loss.total_cycles() < bp_loss.total_cycles(),
                "{}: eco-os loss {} vs bp {}",
                p.id(),
                os_loss.total_cycles(),
                bp_loss.total_cycles()
            );
            let bp_grad = LayerPlan::build(Pass::Grad, Mode::BpIm2col, &p, &cfg()).metrics;
            let is_grad = LayerPlan::build(Pass::Grad, Mode::EcoInputStationary, &p, &cfg()).metrics;
            assert!(
                is_grad.total_cycles() < bp_grad.total_cycles(),
                "{}: eco-is grad {} vs bp {}",
                p.id(),
                is_grad.total_cycles(),
                bp_grad.total_cycles()
            );
            // Off-passes pay the scatter with no skip.
            let os_grad = LayerPlan::build(Pass::Grad, Mode::EcoOutputStationary, &p, &cfg()).metrics;
            let is_loss = LayerPlan::build(Pass::Loss, Mode::EcoInputStationary, &p, &cfg()).metrics;
            assert!(os_grad.total_cycles() > bp_grad.total_cycles(), "{}", p.id());
            assert!(is_loss.total_cycles() > bp_loss.total_cycles(), "{}", p.id());
        }
    }

    #[test]
    fn eco_requests_normalize_bit_identically_to_bp() {
        // No zero-space (stride 1, no dilation) or grouped: the scatter
        // closed forms coincide with BP and the build *normalizes*, so
        // equality is bitwise — including the recorded mode.
        for p in [
            ConvParams::square(56, 64, 64, 3, 1, 1),
            ConvParams::square(56, 128, 128, 3, 2, 1).with_groups(32),
        ] {
            for pass in Pass::ALL {
                let bp = LayerPlan::build(pass, Mode::BpIm2col, &p, &cfg());
                for eco in [Mode::EcoOutputStationary, Mode::EcoInputStationary] {
                    let plan = LayerPlan::build(pass, eco, &p, &cfg());
                    assert_eq!(plan.mode, Mode::BpIm2col, "{} {pass:?}", p.id());
                    assert_eq!(plan.metrics, bp.metrics, "{} {pass:?} {eco:?}", p.id());
                }
            }
        }
    }

    #[test]
    fn autotune_picks_the_min_and_breaks_ties_stably() {
        use crate::accel::strategy::LoweringStrategy;
        let cache = PlanCache::new();
        // Strided layer: the pick differs per pass (OS loss, IS grad).
        let p = ConvParams::square(56, 256, 512, 1, 2, 0);
        let loss = cache.autotune(Pass::Loss, &p, &cfg());
        let grad = cache.autotune(Pass::Grad, &p, &cfg());
        assert_eq!(loss.chosen, Mode::EcoOutputStationary);
        assert_eq!(grad.chosen, Mode::EcoInputStationary);
        for (pass, c) in [(Pass::Loss, &loss), (Pass::Grad, &grad)] {
            let min = c.costs.iter().cloned().fold(f64::INFINITY, f64::min);
            assert_eq!(c.chosen_cost(), min);
            assert_eq!(c.metrics, cache.metrics(pass, c.chosen, &p, &cfg()));
        }
        // Stride-1 layer: every implicit strategy ties exactly; the
        // stable order resolves to BP (earlier than both ecos).
        let q = ConvParams::square(56, 64, 64, 3, 1, 1);
        for pass in Pass::ALL {
            let c = cache.autotune(pass, &q, &cfg());
            assert_eq!(c.chosen, Mode::BpIm2col, "{pass:?}");
            assert_eq!(
                c.costs[LoweringStrategy::BpIm2col.code() as usize],
                c.costs[LoweringStrategy::EcoOutputStationary.code() as usize],
                "{pass:?}: normalized ecos tie bitwise"
            );
        }
        // And Auto is never costlier than any fixed strategy.
        for p in [p, q] {
            for pass in Pass::ALL {
                let c = cache.autotune(pass, &p, &cfg());
                for s in LoweringStrategy::STRATEGIES {
                    let fixed = cfg().objective.cost(&cache.metrics(pass, s, &p, &cfg()));
                    assert!(c.chosen_cost() <= fixed, "{} {pass:?} {s:?}", p.id());
                }
            }
        }
    }

    #[test]
    fn metrics_select_follows_the_config_strategy() {
        use crate::accel::strategy::{LoweringSelect, LoweringStrategy};
        let cache = PlanCache::new();
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        // Default select is Fixed(BpIm2col).
        assert_eq!(
            cache.metrics_select(Pass::Loss, &p, &cfg()),
            cache.metrics(Pass::Loss, Mode::BpIm2col, &p, &cfg())
        );
        let auto = AccelConfig { strategy: LoweringSelect::Auto, ..cfg() };
        assert_eq!(
            cache.metrics_select(Pass::Loss, &p, &auto),
            cache.autotune(Pass::Loss, &p, &auto).metrics
        );
        assert_eq!(cache.strategy_for(Pass::Loss, &p, &auto), Mode::EcoOutputStationary);
        let trad = AccelConfig {
            strategy: LoweringSelect::Fixed(LoweringStrategy::Traditional),
            ..cfg()
        };
        assert_eq!(cache.strategy_for(Pass::Grad, &p, &trad), Mode::Traditional);
    }

    #[test]
    fn config_density_axis_composes_with_the_layer_knob() {
        // Layer at 500/500 with a config scale of 500 behaves like a
        // layer at 250/250 under a dense-scale config.
        let p = ConvParams::square(56, 128, 128, 3, 2, 1).with_density(500, 500);
        let q = ConvParams::square(56, 128, 128, 3, 2, 1).with_density(250, 250);
        let scaled = AccelConfig {
            lowering: SparseLowering::Spots,
            density_millis: 500,
            ..cfg()
        };
        let unscaled = AccelConfig { lowering: SparseLowering::Spots, ..cfg() };
        for pass in Pass::ALL {
            assert_eq!(
                LayerPlan::build(pass, Mode::BpIm2col, &p, &scaled).metrics,
                LayerPlan::build(pass, Mode::BpIm2col, &q, &unscaled).metrics,
                "{pass:?}"
            );
        }
    }
}
