//! GEMM tiling onto the `T x T` array.
//!
//! The stationary matrix B (`K x J`) is cut into `T x T` blocks; the
//! dynamic matrix A (`M x K`) streams through in groups of up to `T`
//! rows. One *stripe* is a column of stationary blocks sharing the same
//! `J` window (`jb`); partial sums accumulate across the `kb` blocks of
//! a stripe.

use crate::conv::ConvParams;
use crate::im2col::pipeline::Pass;
use crate::sim::systolic::block_cycles;
use crate::tensor::ceil_div;

/// Dimensions of a lowered GEMM `A[M x K] . B[K x J]`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    /// Rows of the dynamic matrix A.
    pub m: usize,
    /// Inner (accumulation) dimension.
    pub k: usize,
    /// Columns of the stationary matrix B.
    pub j: usize,
}

impl GemmShape {
    /// The lowered GEMM of a backpropagation pass (paper Eq. 1).
    pub fn from_pass(pass: Pass, p: &ConvParams) -> Self {
        let (m, k, j) = match pass {
            Pass::Loss => p.loss_gemm_dims(),
            Pass::Grad => p.grad_gemm_dims(),
        };
        Self { m, k, j }
    }

    /// Useful MACs of the virtual (dense) GEMM.
    pub fn macs(&self) -> u64 {
        (self.m * self.k * self.j) as u64
    }

    /// Elements of the dynamic panel one stripe stages in buffer A
    /// (`M` rows x `T` lanes). The single home of the "panel must fit
    /// one buffer-A half" working-set rule: the plan builder asserts
    /// it, and the design-space engine's feasibility filter
    /// ([`crate::dse::objective::feasibility`]) rejects candidate
    /// configs by the same formula.
    pub fn dynamic_panel_elems(&self, t: usize) -> usize {
        self.m * t
    }
}

/// Tiling of a [`GemmShape`] onto a `T x T` array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tiling {
    /// Array dimension `T`.
    pub t: usize,
    /// The GEMM being tiled.
    pub shape: GemmShape,
    /// Stationary blocks along K.
    pub n_k: usize,
    /// Stationary stripes along J.
    pub n_j: usize,
    /// Dynamic row groups along M.
    pub n_m: usize,
    /// Rows in the last (possibly partial) M group.
    pub m_last: usize,
}

impl Tiling {
    /// Tile `shape` onto a `t x t` array.
    ///
    /// # Example
    ///
    /// ```
    /// use bp_im2col::accel::tiling::{GemmShape, Tiling};
    ///
    /// // A 17x33 . 33x40 GEMM on the paper's 16x16 array.
    /// let til = Tiling::new(GemmShape { m: 17, k: 33, j: 40 }, 16);
    /// assert_eq!((til.n_m, til.n_k, til.n_j), (2, 3, 3));
    /// assert_eq!(til.m_last, 1); // 17 = 16 + 1
    /// assert_eq!(til.block_passes(), 18); // (3 K-blocks x 3 stripes) x 2
    /// ```
    pub fn new(shape: GemmShape, t: usize) -> Self {
        let n_m = ceil_div(shape.m, t);
        let m_last = if shape.m % t == 0 { t.min(shape.m) } else { shape.m % t };
        Self { t, shape, n_k: ceil_div(shape.k, t), n_j: ceil_div(shape.j, t), n_m, m_last }
    }

    /// Stationary blocks per pass.
    pub fn stationary_blocks(&self) -> usize {
        self.n_k * self.n_j
    }

    /// Total block passes (one per `(kb, jb, mb)`).
    pub fn block_passes(&self) -> usize {
        self.stationary_blocks() * self.n_m
    }

    /// Array cycles of one full stripe (all `kb`, all `mb` groups),
    /// stationary loads hidden by double buffering.
    pub fn stripe_compute_cycles(&self) -> f64 {
        let full = block_cycles(self.t, self.t) as f64;
        let last = block_cycles(self.m_last, self.t) as f64;
        self.n_k as f64 * ((self.n_m as f64 - 1.0) * full + last)
    }

    /// Array cycles of the whole pass.
    pub fn compute_cycles(&self) -> f64 {
        self.n_j as f64 * self.stripe_compute_cycles()
    }

    /// Dense elements streamed from buffer A toward the array
    /// (per-block row groups x T lanes).
    pub fn buffer_a_dense_reads(&self) -> u64 {
        (self.n_k * self.n_j * self.shape.m * self.t) as u64
    }

    /// Dense elements read from buffer B toward the array (stationary
    /// block loads).
    pub fn buffer_b_dense_reads(&self) -> u64 {
        (self.n_k * self.n_j * self.t * self.t) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_layer1_loss_tiling() {
        // 224/3/64/3/2/0 loss: (M,K,J) = (3, 576, 100352).
        let p = ConvParams::square(224, 3, 64, 3, 2, 0);
        let t = Tiling::new(GemmShape::from_pass(Pass::Loss, &p), 16);
        assert_eq!((t.n_k, t.n_j, t.n_m, t.m_last), (36, 6272, 1, 3));
        assert_eq!(t.stationary_blocks(), 225_792);
        // DESIGN.md §5: ~33 cycles per block pass, ~7.45M total — within
        // ~20 % of the paper's 8,929,989.
        let c = t.compute_cycles();
        assert!((7.0e6..8.0e6).contains(&c), "{c}");
    }

    #[test]
    fn table2_layer2_loss_close_to_paper() {
        // 112/64/64/3/2/1 loss: paper computation 10,329,856 cycles.
        let p = ConvParams::square(112, 64, 64, 3, 2, 1);
        let t = Tiling::new(GemmShape::from_pass(Pass::Loss, &p), 16);
        let c = t.compute_cycles();
        assert!((c - 10_329_856.0).abs() / 10_329_856.0 < 0.05, "{c}");
    }

    #[test]
    fn table2_layer1_grad_close_to_paper() {
        // 224/3/64/3/2/0 grad: paper computation 2,274,645 cycles.
        let p = ConvParams::square(224, 3, 64, 3, 2, 0);
        let t = Tiling::new(GemmShape::from_pass(Pass::Grad, &p), 16);
        let c = t.compute_cycles();
        assert!((c - 2_274_645.0).abs() / 2_274_645.0 < 0.05, "{c}");
    }

    #[test]
    fn partial_tiles_counted() {
        let t = Tiling::new(GemmShape { m: 17, k: 17, j: 17 }, 16);
        assert_eq!((t.n_k, t.n_j, t.n_m, t.m_last), (2, 2, 2, 1));
        assert_eq!(t.block_passes(), 8);
    }

    #[test]
    fn exact_tiles_have_full_last_group() {
        let t = Tiling::new(GemmShape { m: 32, k: 16, j: 16 }, 16);
        assert_eq!((t.n_m, t.m_last), (2, 16));
    }

    #[test]
    fn dense_read_counts() {
        let t = Tiling::new(GemmShape { m: 8, k: 32, j: 48 }, 16);
        assert_eq!(t.buffer_b_dense_reads(), (2 * 3 * 256) as u64);
        assert_eq!(t.buffer_a_dense_reads(), (2 * 3 * 8 * 16) as u64);
    }
}
