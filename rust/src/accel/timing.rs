//! Analytic cycle/traffic engine — the full-size-layer simulator behind
//! Tables II–III and Figures 6–8.
//!
//! Since coordinator v2 the pass model itself lives in
//! [`crate::accel::plan::LayerPlan::build`]; [`simulate_pass`] builds an
//! uncached plan and returns its metrics, and
//! [`crate::accel::plan::PlanCache`] memoizes plans for callers that
//! replay layer geometries. This module keeps the dilated-mode window
//! classifiers ([`grad_zero_windows`], run-crossing counting) the plan
//! builder uses.
//!
//! Model summary (DESIGN.md §5 documents the calibration against the
//! paper's Table II; component costs within ~±20 %):
//!
//! * One `m x T x T` block pass costs `m + 2T - 2` array cycles
//!   (skew fill + stream + drain); stationary loads hide behind double
//!   buffering.
//! * Prologue (Table III) is paid once per stationary stripe by each
//!   address-generation pipeline that restarts there.
//! * The baseline additionally pays the zero-space reorganization
//!   (`sim::reorg_engine`) before the pass can start, and streams the
//!   zero-spaced operand through DRAM and the on-chip buffers.
//! * BP-im2col streams only compact data plus 6 bytes of base address +
//!   mask per 16-element window; in dilated mode, windows whose non-zero
//!   lanes map to more than one contiguous compact run pay one extra
//!   fetch cycle per additional run.
//! * DRAM fills overlap compute per stripe; any excess is a stall.
//! * Grouped layers run `G` per-group GEMMs back to back: compute,
//!   prologue, buffer reads and output traffic scale by `G` over the
//!   per-group tiling, while the reorganization pass (whole `dY`) is
//!   paid once per layer.

use crate::accel::config::AccelConfig;
use crate::accel::metrics::{LayerMetrics, PassMetrics};
use crate::accel::plan::LayerPlan;
use crate::conv::ConvParams;
use crate::im2col::pipeline::{Mode, Pass};

/// Bytes of side-band metadata per 16-lane window (4-byte base address +
/// 2-byte mask, `sim::compress`).
pub(crate) const META_BYTES_PER_WINDOW: u64 = 6;

/// Count the `kb` windows of the dilated-mode dynamic matrix whose lanes
/// are ALL structural zeros (the window lies entirely inside
/// zero-inserted rows) — the blocks the `sparse_skip` future-work option
/// elides. A lane at flat position `q` (within `B*Ho''*Wo''`) is
/// non-zero iff `h % Sh == 0 && w % Sw == 0` for its `(h, w)`. The
/// window pattern is identical for every matrix row and every group.
pub fn grad_zero_windows(p: &ConvParams, t: usize) -> usize {
    let (h2, w2) = (p.ho2(), p.wo2());
    let k = p.b * h2 * w2;
    let mut zero = 0usize;
    let mut start = 0usize;
    while start < k {
        let end = (start + t).min(k);
        let mut any_nz = false;
        // A window spans at most two (b, h) rows; test lane-by-lane only
        // within the first/last partial rows, full rows by arithmetic.
        let mut q = start;
        while q < end {
            let w = q % w2;
            let h = (q / w2) % h2;
            if h % p.sh == 0 {
                // Row contains non-zeros every Sw lanes; the window
                // segment [w, min(w2, w + remaining)) contains one iff a
                // multiple of Sw falls inside.
                let seg_end = (w + (end - q)).min(w2);
                let first_mult = w.div_ceil(p.sw) * p.sw;
                if first_mult < seg_end {
                    any_nz = true;
                    break;
                }
                q += seg_end - w;
            } else {
                // Whole row segment is zero; skip to the next row.
                q += w2 - w;
            }
        }
        if !any_nz {
            zero += 1;
        }
        start += t;
    }
    zero
}

/// Count the `kb` windows of the dilated-mode dynamic matrix whose 16
/// virtual lanes span a compact-row boundary (the non-zero lanes then map
/// to 2 contiguous runs and the fetch splits in two).
pub(crate) fn grad_window_crossings(p: &ConvParams, t: usize) -> usize {
    let w2 = p.wo2();
    let k = p.b * p.ho2() * w2;
    let mut crossings = 0;
    let mut start = 0;
    while start < k {
        let end = (start + t - 1).min(k - 1);
        // Lane positions within the (b, h) row of length Wo''.
        if start / w2 != end / w2 {
            crossings += 1;
        }
        start += t;
    }
    crossings
}

/// Simulate one backpropagation pass of one layer.
///
/// This is the *cold* path: it derives a fresh [`LayerPlan`] and returns
/// its metrics. Callers that replay layer geometries (training loops,
/// network sweeps, fleets) should go through
/// [`crate::accel::plan::PlanCache`] instead, which memoizes the plan and
/// returns bit-identical metrics.
///
/// # Example
///
/// ```
/// use bp_im2col::accel::{simulate_pass, AccelConfig};
/// use bp_im2col::im2col::pipeline::{Mode, Pass};
/// use bp_im2col::ConvParams;
///
/// let p = ConvParams::square(56, 256, 512, 1, 2, 0); // Table II row 3
/// let cfg = AccelConfig::default();
/// let trad = simulate_pass(Pass::Loss, Mode::Traditional, &p, &cfg);
/// let bp = simulate_pass(Pass::Loss, Mode::BpIm2col, &p, &cfg);
/// // Eliminating the reorganization makes BP-im2col strictly cheaper.
/// assert!(bp.total_cycles() < trad.total_cycles());
/// assert_eq!(bp.reorg_cycles, 0.0);
/// ```
pub fn simulate_pass(pass: Pass, mode: Mode, p: &ConvParams, cfg: &AccelConfig) -> PassMetrics {
    LayerPlan::build(pass, mode, p, cfg).metrics
}

/// Simulate both passes of one layer.
pub fn simulate_layer(mode: Mode, p: &ConvParams, cfg: &AccelConfig) -> LayerMetrics {
    LayerMetrics {
        loss: simulate_pass(Pass::Loss, mode, p, cfg),
        grad: simulate_pass(Pass::Grad, mode, p, cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::metrics::speedup;

    fn cfg() -> AccelConfig {
        AccelConfig::default()
    }

    fn t2_layers() -> [ConvParams; 5] {
        [
            ConvParams::square(224, 3, 64, 3, 2, 0),
            ConvParams::square(112, 64, 64, 3, 2, 1),
            ConvParams::square(56, 256, 512, 1, 2, 0),
            ConvParams::square(28, 244, 244, 3, 2, 1),
            ConvParams::square(14, 1024, 2048, 1, 2, 0),
        ]
    }

    #[test]
    fn bp_always_wins_on_stride2_layers() {
        for p in t2_layers() {
            for pass in Pass::ALL {
                let trad = simulate_pass(pass, Mode::Traditional, &p, &cfg());
                let bp = simulate_pass(pass, Mode::BpIm2col, &p, &cfg());
                assert!(
                    speedup(&trad, &bp) > 1.0,
                    "{} {:?}: trad {} bp {}",
                    p.id(),
                    pass,
                    trad.total_cycles(),
                    bp.total_cycles()
                );
            }
        }
    }

    #[test]
    fn bp_wins_on_generalized_layers_too() {
        // Dilated (DeepLab-style), grouped (ResNeXt-style) and depthwise
        // layers: BP-im2col must stay strictly cheaper in cycles and
        // traffic.
        for p in [
            ConvParams::square(28, 256, 256, 3, 1, 2).with_dilation(2, 2),
            ConvParams::square(28, 512, 512, 3, 1, 4).with_dilation(4, 4),
            ConvParams::square(56, 128, 128, 3, 2, 1).with_groups(32),
            ConvParams::square(112, 64, 64, 3, 2, 1).with_groups(64),
            ConvParams::square(56, 64, 64, 3, 1, 1).with_stride(2, 1),
        ] {
            p.validate().unwrap();
            for pass in Pass::ALL {
                let trad = simulate_pass(pass, Mode::Traditional, &p, &cfg());
                let bp = simulate_pass(pass, Mode::BpIm2col, &p, &cfg());
                assert!(
                    bp.total_cycles() < trad.total_cycles(),
                    "{} {pass:?}: cycles {} vs {}",
                    p.id(),
                    bp.total_cycles(),
                    trad.total_cycles()
                );
                assert!(
                    bp.traffic.total() < trad.traffic.total(),
                    "{} {pass:?}: traffic {} vs {}",
                    p.id(),
                    bp.traffic.total(),
                    trad.traffic.total()
                );
            }
        }
    }

    #[test]
    fn grouped_layer_totals_scale_from_per_group_gemm() {
        // A grouped layer's compute is G x the per-group tiling, and its
        // MACs are 1/G of the dense layer's (fewer cross-channel terms).
        let dense = ConvParams::square(56, 128, 128, 3, 2, 1);
        let grouped = dense.with_groups(32);
        for pass in Pass::ALL {
            let d = simulate_pass(pass, Mode::BpIm2col, &dense, &cfg());
            let g = simulate_pass(pass, Mode::BpIm2col, &grouped, &cfg());
            assert_eq!(d.macs, 32 * g.macs, "{pass:?}");
            assert!(g.compute_cycles < d.compute_cycles, "{pass:?}");
        }
    }

    #[test]
    fn layer1_speedups_dominated_by_reorg() {
        // Table II row 1: the paper's biggest wins (5.13x loss, 16.29x
        // grad) come from eliminating a reorganization that dwarfs the
        // computation. Our substitution preserves the effect.
        let p = ConvParams::square(224, 3, 64, 3, 2, 0);
        let loss_tr = simulate_pass(Pass::Loss, Mode::Traditional, &p, &cfg());
        let grad_tr = simulate_pass(Pass::Grad, Mode::Traditional, &p, &cfg());
        assert!(loss_tr.reorg_cycles > loss_tr.compute_cycles);
        assert!(grad_tr.reorg_cycles > grad_tr.compute_cycles);
        let loss_bp = simulate_pass(Pass::Loss, Mode::BpIm2col, &p, &cfg());
        let grad_bp = simulate_pass(Pass::Grad, Mode::BpIm2col, &p, &cfg());
        assert!(speedup(&loss_tr, &loss_bp) > 2.0);
        assert!(speedup(&grad_tr, &grad_bp) > 5.0);
    }

    #[test]
    fn bp_compute_close_to_traditional_compute() {
        // Table II: BP cycles track the baseline's pure computation
        // within a few percent (the win is eliminating reorganization).
        for p in t2_layers() {
            for pass in Pass::ALL {
                let trad = simulate_pass(pass, Mode::Traditional, &p, &cfg());
                let bp = simulate_pass(pass, Mode::BpIm2col, &p, &cfg());
                let trad_comp = trad.compute_cycles + trad.prologue_cycles;
                let ratio = bp.total_cycles() / trad_comp;
                assert!((0.95..1.15).contains(&ratio), "{} {:?}: ratio {ratio}", p.id(), pass);
            }
        }
    }

    #[test]
    fn buffer_bandwidth_reduction_close_to_sparsity() {
        // Fig. 8: "the ratio of the bandwidth occupation reduction ... is
        // close to the sparsity of the loss of the output".
        for p in t2_layers() {
            let trad = simulate_pass(Pass::Loss, Mode::Traditional, &p, &cfg());
            let bp = simulate_pass(Pass::Loss, Mode::BpIm2col, &p, &cfg());
            let red = 1.0 - bp.buffer_b_reads as f64 / trad.buffer_b_reads as f64;
            assert!((red - bp.sparsity).abs() < 0.02, "{}: {red} vs {}", p.id(), bp.sparsity);

            let trad_g = simulate_pass(Pass::Grad, Mode::Traditional, &p, &cfg());
            let bp_g = simulate_pass(Pass::Grad, Mode::BpIm2col, &p, &cfg());
            let red_a = 1.0 - bp_g.buffer_a_reads as f64 / trad_g.buffer_a_reads as f64;
            assert!((red_a - bp_g.sparsity).abs() < 0.02, "{}: {red_a}", p.id());
        }
    }

    #[test]
    fn offchip_traffic_reduced_at_least_paper_floor() {
        // §Abstract: off-chip bandwidth reduced by at least 22.7 %.
        for p in t2_layers() {
            for pass in Pass::ALL {
                let trad = simulate_pass(pass, Mode::Traditional, &p, &cfg());
                let bp = simulate_pass(pass, Mode::BpIm2col, &p, &cfg());
                let red = 1.0 - bp.traffic.total() as f64 / trad.traffic.total() as f64;
                assert!(red > 0.227, "{} {:?}: reduction {red}", p.id(), pass);
            }
        }
    }

    #[test]
    fn storage_overhead_reduced_at_least_paper_floor() {
        // §Abstract: additional storage overhead reduced by >= 74.78 %.
        for p in t2_layers() {
            for pass in Pass::ALL {
                let trad = simulate_pass(pass, Mode::Traditional, &p, &cfg());
                let bp = simulate_pass(pass, Mode::BpIm2col, &p, &cfg());
                let red = 1.0 - bp.storage_overhead_bytes as f64 / trad.storage_overhead_bytes as f64;
                assert!(red >= 0.7478, "{} {:?}: reduction {red}", p.id(), pass);
            }
        }
    }

    #[test]
    fn low_bandwidth_stalls_baseline_harder() {
        // The paper's motivation: zero traffic hurts most when bandwidth
        // and compute are mismatched.
        // Layer 1's gradient pass streams a 6.25M-element zero-inflated
        // dynamic matrix over only two stripes: at 1 elem/cycle the
        // baseline's fills no longer hide behind compute, BP's do.
        let p = ConvParams::square(224, 3, 64, 3, 2, 0);
        let lo = AccelConfig::bandwidth_limited(1.0);
        let trad = simulate_pass(Pass::Grad, Mode::Traditional, &p, &lo);
        let bp = simulate_pass(Pass::Grad, Mode::BpIm2col, &p, &lo);
        assert!(
            trad.stall_cycles > bp.stall_cycles,
            "trad {} bp {}",
            trad.stall_cycles,
            bp.stall_cycles
        );
    }

    #[test]
    fn crossings_counted_only_at_row_boundaries() {
        let p = ConvParams::square(9, 1, 1, 3, 2, 1);
        // Wo'' = 9: windows of 16 virtual lanes almost always cross.
        assert!(grad_window_crossings(&p, 16) > 0);
        // A Wo'' that is a multiple of 16 never crosses.
        let p2 = ConvParams::basic(1, 1, 33, 33, 1, 3, 3, 2, 1, 1);
        assert_eq!(p2.wo2(), 33);
        assert!(grad_window_crossings(&p2, 16) > 0); // 33 % 16 != 0
    }

    #[test]
    fn sparse_skip_elides_only_zero_windows() {
        // For stride 2, roughly (S-1)/S of the rows are pure insertions;
        // skipping them should cut BP grad compute by ~40-50 % without
        // touching the baseline or the loss pass.
        let p = ConvParams::square(56, 256, 512, 1, 2, 0);
        let base = cfg();
        let skip = AccelConfig { sparse_skip: true, ..base };
        let g0 = simulate_pass(Pass::Grad, Mode::BpIm2col, &p, &base);
        let g1 = simulate_pass(Pass::Grad, Mode::BpIm2col, &p, &skip);
        let ratio = g1.compute_cycles / g0.compute_cycles;
        assert!((0.40..0.70).contains(&ratio), "ratio {ratio}");
        // Baseline and loss pass unaffected.
        assert_eq!(
            simulate_pass(Pass::Grad, Mode::Traditional, &p, &skip).compute_cycles,
            simulate_pass(Pass::Grad, Mode::Traditional, &p, &base).compute_cycles
        );
        assert_eq!(
            simulate_pass(Pass::Loss, Mode::BpIm2col, &p, &skip).compute_cycles,
            simulate_pass(Pass::Loss, Mode::BpIm2col, &p, &base).compute_cycles
        );
    }

    #[test]
    fn zero_window_count_brute_force_check() {
        // Cross-check the arithmetic window classifier against a direct
        // per-lane enumeration, including asymmetric strides.
        for p in [
            ConvParams::square(9, 1, 1, 3, 2, 1),
            ConvParams::square(14, 4, 4, 3, 2, 1),
            ConvParams::basic(2, 1, 11, 7, 1, 3, 2, 3, 1, 0),
            ConvParams::basic(1, 1, 12, 9, 1, 3, 3, 1, 1, 1).with_stride(2, 3),
            ConvParams::basic(1, 1, 9, 12, 1, 3, 3, 1, 1, 1).with_stride(3, 2),
        ] {
            let t = 16;
            let (h2, w2) = (p.ho2(), p.wo2());
            let k = p.b * h2 * w2;
            let mut brute = 0;
            let mut start = 0;
            while start < k {
                let end = (start + t).min(k);
                let any = (start..end).any(|q| {
                    let w = q % w2;
                    let h = (q / w2) % h2;
                    h % p.sh == 0 && w % p.sw == 0
                });
                if !any {
                    brute += 1;
                }
                start += t;
            }
            assert_eq!(grad_zero_windows(&p, t), brute, "{p:?}");
        }
    }

    #[test]
    fn grad_macs_equal_both_modes() {
        let p = ConvParams::square(56, 256, 512, 1, 2, 0);
        let a = simulate_pass(Pass::Grad, Mode::Traditional, &p, &cfg());
        let b = simulate_pass(Pass::Grad, Mode::BpIm2col, &p, &cfg());
        assert_eq!(a.macs, b.macs);
    }
}
