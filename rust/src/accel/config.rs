//! Accelerator configuration (the paper's TPU-like platform).

use crate::accel::strategy::{AutoObjective, LoweringSelect};
use crate::sim::dram::DramModel;
use crate::sparse::SparseLowering;

/// Hardware parameters of the simulated accelerator. Defaults match the
/// paper's evaluation platform where stated (16x16 array, FP32,
/// double-buffered A/B buffers, "sufficient network bandwidth" for the
/// prologue experiment) and are documented substitutions elsewhere
/// (DESIGN.md §Substitutions).
#[derive(Clone, Copy, Debug)]
pub struct AccelConfig {
    /// Systolic array dimension `T` (the paper: 16).
    pub array_dim: usize,
    /// Off-chip memory model. Default is a high-bandwidth setting
    /// (16 elems/cycle = 64 B/cycle) matching the paper's "sufficient
    /// network bandwidth"; `examples/bandwidth_explorer.rs` sweeps it.
    pub dram: DramModel,
    /// Half-capacity of double-buffered buffer A, in elements.
    pub buf_a_half: usize,
    /// Half-capacity of double-buffered buffer B, in elements.
    pub buf_b_half: usize,
    /// DMA cost of the *baseline's* zero-space reorganization, in cycles
    /// per destination element (address computation + write issue,
    /// serialized in the DMA walker). See `sim::reorg_engine`.
    pub reorg_cycles_per_elem: f64,
    /// The paper's future work ("we will further optimize sparse
    /// computation"): when enabled, BP-im2col's dilated mode *skips*
    /// dynamic-matrix windows whose 16 lanes are all structural zeros
    /// (entire zero-inserted rows) instead of streaming crossbar-
    /// re-inflated zeros through the array. Off by default (matches the
    /// paper's evaluated design, which "does not support sparse
    /// computation at this stage").
    pub sparse_skip: bool,
    /// How GEMMs are lowered with respect to **data** sparsity
    /// (pruned weights / sparse activations — DESIGN.md §14):
    /// [`SparseLowering::Dense`] streams every value (the paper's
    /// design); the other variants model column combining and a
    /// SPOTS-style sparse pipeline. Orthogonal to `sparse_skip`, which
    /// skips *structural* zero windows.
    pub lowering: SparseLowering,
    /// Config-level density scale in fixed-point thousandths
    /// (`1..=1000`), composed multiplicatively with each layer's own
    /// [`crate::sparse::Density`] — the DSE `density` axis. 1000
    /// (dense, the default) is the exact identity.
    pub density_millis: usize,
    /// How the planner picks the **structural** lowering strategy per
    /// layer/pass (DESIGN.md §15): a fixed
    /// [`crate::accel::strategy::LoweringStrategy`] for every layer
    /// (default: the paper's BP-im2col), or `auto` — score every
    /// strategy per `(layer, pass)` and take the minimum under
    /// [`AccelConfig::objective`]. The CLI `--lowering-strategy` /
    /// config-file `lowering_strategy` knob and the DSE
    /// `lowering_strategy` axis.
    pub strategy: LoweringSelect,
    /// Cost function the `auto` strategy selection minimizes (config
    /// file key `objective`; default runtime). Inert under a fixed
    /// strategy.
    pub objective: AutoObjective,
}

impl Default for AccelConfig {
    fn default() -> Self {
        Self {
            array_dim: 16,
            dram: DramModel::with_bandwidth(16.0),
            // 128 KiB halves (32 Ki FP32 elements) — TPU-class on-chip
            // SRAM scaled to a 16x16 array.
            buf_a_half: 32 * 1024,
            buf_b_half: 32 * 1024,
            reorg_cycles_per_elem: 4.0,
            sparse_skip: false,
            lowering: SparseLowering::Dense,
            density_millis: 1000,
            strategy: LoweringSelect::default(),
            objective: AutoObjective::default(),
        }
    }
}

impl AccelConfig {
    /// A bandwidth-constrained variant (the paper's motivation about
    /// "processors with mismatched bandwidth and computing power").
    /// Burst shape comes from [`DramModel::with_bandwidth`], the same
    /// constructor the default platform and the DSE axes use — the
    /// burst constants live in exactly one place.
    pub fn bandwidth_limited(elems_per_cycle: f64) -> Self {
        Self { dram: DramModel::with_bandwidth(elems_per_cycle), ..Self::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_platform() {
        let c = AccelConfig::default();
        assert_eq!(c.array_dim, 16);
        assert!(c.buf_a_half >= 16 * 1024);
        // The paper's design is dense: no data-sparsity lowering, no
        // density scaling.
        assert_eq!(c.lowering, SparseLowering::Dense);
        assert_eq!(c.density_millis, 1000);
        // And lowers everything with BP-im2col under the runtime
        // objective (the autotuner is opt-in).
        use crate::accel::strategy::LoweringStrategy;
        assert_eq!(c.strategy, LoweringSelect::Fixed(LoweringStrategy::BpIm2col));
        assert_eq!(c.objective, AutoObjective::Runtime);
    }

    #[test]
    fn bandwidth_limited_only_changes_dram() {
        let c = AccelConfig::bandwidth_limited(2.0);
        assert_eq!(c.dram.elems_per_cycle, 2.0);
        assert_eq!(c.array_dim, AccelConfig::default().array_dim);
    }

    #[test]
    fn burst_constants_come_from_one_constructor() {
        // Default platform, bandwidth_limited and DramModel::default
        // must agree on the burst shape — with_bandwidth is the single
        // home of those constants.
        let d = DramModel::default();
        for cfg in [AccelConfig::default(), AccelConfig::bandwidth_limited(2.0)] {
            assert_eq!(cfg.dram.burst_overhead, d.burst_overhead);
            assert_eq!(cfg.dram.burst_len, d.burst_len);
        }
    }
}
