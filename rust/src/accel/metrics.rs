//! Metrics collected by the accelerator models — the raw material of
//! every table and figure in the paper's evaluation.

use crate::im2col::pipeline::{Mode, Pass};
use crate::sim::dram::DramTraffic;

/// All counters of one backpropagation pass on one layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PassMetrics {
    /// Which backpropagation pass these metrics describe.
    pub pass: Pass,
    /// Which im2col algorithm produced them.
    pub mode: Mode,
    /// Pure array cycles (block passes, fills, drains).
    pub compute_cycles: f64,
    /// Baseline-only zero-space reorganization (Table II's column).
    pub reorg_cycles: f64,
    /// Address-pipeline prologues (Table III), summed over stripes.
    pub prologue_cycles: f64,
    /// DRAM fill cycles not hidden by double buffering.
    pub stall_cycles: f64,
    /// Extra fetch cycles from compressed-run splits (dilated mode).
    pub extra_fetch_cycles: f64,
    /// Off-chip traffic of the pass.
    pub traffic: DramTraffic,
    /// Elements read from buffer A toward the array (Fig. 8b).
    pub buffer_a_reads: u64,
    /// Elements read from buffer B toward the array (Fig. 8a).
    pub buffer_b_reads: u64,
    /// Extra DRAM storage the mode requires beyond the compact tensors
    /// (baseline: the zero-spaced copy; BP: masks + base addresses).
    pub storage_overhead_bytes: u64,
    /// Structural sparsity of the zero-spaced operand of this pass.
    pub sparsity: f64,
    /// Dense MACs of the virtual GEMM (same in both modes).
    pub macs: u64,
}

impl PassMetrics {
    /// End-to-end runtime of the pass in cycles.
    pub fn total_cycles(&self) -> f64 {
        self.compute_cycles
            + self.reorg_cycles
            + self.prologue_cycles
            + self.stall_cycles
            + self.extra_fetch_cycles
    }

    /// Array utilization: useful MACs / (PEs * total cycles).
    pub fn utilization(&self, array_dim: usize) -> f64 {
        self.macs as f64 / ((array_dim * array_dim) as f64 * self.total_cycles())
    }
}

/// Loss + gradient metrics of one layer under one mode.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerMetrics {
    /// Loss-calculation (`dX`) metrics.
    pub loss: PassMetrics,
    /// Gradient-calculation (`dW`) metrics.
    pub grad: PassMetrics,
}

impl LayerMetrics {
    /// Backward runtime of the layer: loss + gradient cycles.
    pub fn total_cycles(&self) -> f64 {
        self.loss.total_cycles() + self.grad.total_cycles()
    }

    /// Metrics of the given pass.
    pub fn get(&self, pass: Pass) -> &PassMetrics {
        match pass {
            Pass::Loss => &self.loss,
            Pass::Grad => &self.grad,
        }
    }
}

/// Speedup of `ours` over `baseline` (the paper's Table II column).
pub fn speedup(baseline: &PassMetrics, ours: &PassMetrics) -> f64 {
    baseline.total_cycles() / ours.total_cycles()
}

/// Percentage reduction of a quantity: `(base - ours) / base * 100`.
pub fn reduction_pct(base: f64, ours: f64) -> f64 {
    if base == 0.0 {
        return 0.0;
    }
    (base - ours) / base * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(pass: Pass, mode: Mode, compute: f64, reorg: f64) -> PassMetrics {
        PassMetrics {
            pass,
            mode,
            compute_cycles: compute,
            reorg_cycles: reorg,
            prologue_cycles: 0.0,
            stall_cycles: 0.0,
            extra_fetch_cycles: 0.0,
            traffic: DramTraffic::default(),
            buffer_a_reads: 0,
            buffer_b_reads: 0,
            storage_overhead_bytes: 0,
            sparsity: 0.0,
            macs: 0,
        }
    }

    #[test]
    fn total_is_component_sum() {
        let m = dummy(Pass::Loss, Mode::Traditional, 100.0, 50.0);
        assert_eq!(m.total_cycles(), 150.0);
    }

    #[test]
    fn speedup_matches_paper_definition() {
        // Table II: speedup = (trad computation + reorganization) / BP.
        let trad = dummy(Pass::Loss, Mode::Traditional, 8_929_989.0, 37_083_360.0);
        let bp = dummy(Pass::Loss, Mode::BpIm2col, 8_962_102.0, 0.0);
        let s = speedup(&trad, &bp);
        assert!((s - 5.13).abs() < 0.01, "{s}");
    }

    #[test]
    fn reduction_pct_basics() {
        assert_eq!(reduction_pct(200.0, 100.0), 50.0);
        assert_eq!(reduction_pct(0.0, 5.0), 0.0);
    }
}
