//! Config-file loading for the accelerator (`key = value` format).
//!
//! The offline image has no serde/toml (and the default build carries no
//! external dependencies at all), so the parser and its error type are
//! hand-rolled: one `key = value` per line, `#` comments, unknown keys
//! rejected (a typo must not silently fall back to a default). See
//! `configs/*.cfg` for the shipped platform presets.

use std::fmt;
use std::path::Path;

use crate::accel::config::AccelConfig;

/// Config-parsing error: a human-readable message chain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConfigError(String);

impl ConfigError {
    fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }

    /// Prepend a context line (mirrors `anyhow::Context` formatting with
    /// `{:#}`: `context: cause`).
    fn context(self, ctx: impl fmt::Display) -> Self {
        Self(format!("{ctx}: {}", self.0))
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Parse an accelerator config from `key = value` text, starting from
/// the defaults.
///
/// Strict like the CLI scanner: unknown keys, malformed values,
/// **duplicate keys** (last-wins would silently drop the earlier
/// setting) and out-of-range values are all errors, each naming the
/// offending line.
pub fn parse(text: &str) -> Result<AccelConfig, ConfigError> {
    let mut cfg = AccelConfig::default();
    let mut seen: Vec<String> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(ConfigError::new(format!(
                "line {}: expected `key = value`, got {raw:?}",
                lineno + 1
            )));
        };
        let (key, value) = (key.trim(), value.trim());
        if seen.iter().any(|k| k == key) {
            return Err(ConfigError::new(format!(
                "line {}: duplicate key {key:?} (each key may appear once)",
                lineno + 1
            )));
        }
        seen.push(key.to_string());
        let bad = || ConfigError::new(format!("line {}: bad value for {key}: {value:?}", lineno + 1));
        match key {
            "array_dim" => cfg.array_dim = value.parse().map_err(|_| bad())?,
            "dram_elems_per_cycle" => cfg.dram.elems_per_cycle = value.parse().map_err(|_| bad())?,
            "dram_burst_overhead" => cfg.dram.burst_overhead = value.parse().map_err(|_| bad())?,
            "dram_burst_len" => cfg.dram.burst_len = value.parse().map_err(|_| bad())?,
            "buf_a_half" => cfg.buf_a_half = value.parse().map_err(|_| bad())?,
            "buf_b_half" => cfg.buf_b_half = value.parse().map_err(|_| bad())?,
            "reorg_cycles_per_elem" => cfg.reorg_cycles_per_elem = value.parse().map_err(|_| bad())?,
            "sparse_skip" => cfg.sparse_skip = value.parse().map_err(|_| bad())?,
            "lowering" => {
                cfg.lowering = crate::sparse::SparseLowering::parse(value)
                    .map_err(|e| ConfigError::new(format!("line {}: {e}", lineno + 1)))?
            }
            "density_millis" => cfg.density_millis = value.parse().map_err(|_| bad())?,
            "lowering_strategy" => {
                cfg.strategy = crate::accel::strategy::LoweringSelect::parse(value)
                    .map_err(|e| ConfigError::new(format!("line {}: {e}", lineno + 1)))?
            }
            "objective" => {
                cfg.objective = crate::accel::strategy::AutoObjective::parse(value)
                    .map_err(|e| ConfigError::new(format!("line {}: {e}", lineno + 1)))?
            }
            other => {
                return Err(ConfigError::new(format!("line {}: unknown key {other:?}", lineno + 1)))
            }
        }
        // Per-key range errors carry the line number too — a preset
        // with `array_dim = 32` fails pointing at its own line, not
        // with a whole-file message after parsing. The predicate itself
        // is shared with [`validate`], so the two can never drift.
        if let Some(msg) = field_range_error(key, &cfg) {
            return Err(ConfigError::new(format!("line {}: {msg}", lineno + 1)));
        }
    }
    validate(&cfg)?;
    Ok(cfg)
}

/// Render a config back into the `key = value` file format [`parse`]
/// reads — every key, in a fixed order, so `parse(&render(&cfg))`
/// reproduces `cfg` exactly (floats use the shortest round-trip form).
pub fn render(cfg: &AccelConfig) -> String {
    format!(
        "array_dim = {}\n\
         dram_elems_per_cycle = {}\n\
         dram_burst_overhead = {}\n\
         dram_burst_len = {}\n\
         buf_a_half = {}\n\
         buf_b_half = {}\n\
         reorg_cycles_per_elem = {}\n\
         sparse_skip = {}\n\
         lowering = {}\n\
         density_millis = {}\n\
         lowering_strategy = {}\n\
         objective = {}\n",
        cfg.array_dim,
        cfg.dram.elems_per_cycle,
        cfg.dram.burst_overhead,
        cfg.dram.burst_len,
        cfg.buf_a_half,
        cfg.buf_b_half,
        cfg.reorg_cycles_per_elem,
        cfg.sparse_skip,
        cfg.lowering.name(),
        cfg.density_millis,
        cfg.strategy.name(),
        cfg.objective.name(),
    )
}

/// Load a config file.
pub fn load(path: impl AsRef<Path>) -> Result<AccelConfig, ConfigError> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| ConfigError::new(format!("reading {}: {e}", path.display())))?;
    parse(&text).map_err(|e| e.context(format!("parsing {}", path.display())))
}

/// Largest supported array dimension (compress/crossbar lane masks are
/// `u16` — one bit per lane).
pub const MAX_ARRAY_DIM: usize = 16;

/// Largest supported buffer half, in elements (4 Gi elements = 16 GiB
/// of SRAM per half — far beyond silicon, close enough to keep every
/// downstream byte computation inside `usize`/`f64`).
pub const MAX_BUF_HALF: usize = 1 << 32;

/// Largest supported DRAM burst length, in elements.
pub const MAX_BURST_LEN: usize = 1 << 24;

/// Largest supported DRAM rate, in elements/cycle.
pub const MAX_DRAM_RATE: f64 = 1e6;

/// Largest supported per-burst / per-element cycle cost.
pub const MAX_COST_CYCLES: f64 = 1e9;

/// Range error of one config field (named in config-file key syntax),
/// if any. The single home of the per-field domain predicates: [`parse`]
/// applies it per assigned key (wrapping the message with the line
/// number), [`validate`] applies it to every field, and the DSE axis
/// validation ([`crate::dse::space::SpaceSpec::validate`]) enforces the
/// same `MAX_*` bounds — so the three front ends cannot drift apart.
fn field_range_error(key: &str, cfg: &AccelConfig) -> Option<String> {
    match key {
        "array_dim" => (cfg.array_dim == 0 || cfg.array_dim > MAX_ARRAY_DIM).then(|| {
            format!(
                "array_dim must be in 1..={MAX_ARRAY_DIM} (lane masks are u16), got {}",
                cfg.array_dim
            )
        }),
        "dram_elems_per_cycle" => {
            let v = cfg.dram.elems_per_cycle;
            (!v.is_finite() || v <= 0.0 || v > MAX_DRAM_RATE).then(|| {
                format!(
                    "dram_elems_per_cycle must be positive, finite and at most \
                     {MAX_DRAM_RATE}, got {v}"
                )
            })
        }
        "dram_burst_overhead" => {
            let v = cfg.dram.burst_overhead;
            (!v.is_finite() || v < 0.0 || v > MAX_COST_CYCLES).then(|| {
                format!(
                    "dram_burst_overhead must be non-negative, finite and at most \
                     {MAX_COST_CYCLES}, got {v}"
                )
            })
        }
        "dram_burst_len" => (cfg.dram.burst_len == 0 || cfg.dram.burst_len > MAX_BURST_LEN)
            .then(|| {
                format!("dram_burst_len must be in 1..={MAX_BURST_LEN}, got {}", cfg.dram.burst_len)
            }),
        "buf_a_half" => (cfg.buf_a_half == 0 || cfg.buf_a_half > MAX_BUF_HALF)
            .then(|| format!("buf_a_half must be in 1..={MAX_BUF_HALF}, got {}", cfg.buf_a_half)),
        "buf_b_half" => (cfg.buf_b_half == 0 || cfg.buf_b_half > MAX_BUF_HALF)
            .then(|| format!("buf_b_half must be in 1..={MAX_BUF_HALF}, got {}", cfg.buf_b_half)),
        "reorg_cycles_per_elem" => {
            let v = cfg.reorg_cycles_per_elem;
            (!v.is_finite() || v < 0.0 || v > MAX_COST_CYCLES).then(|| {
                format!(
                    "reorg_cycles_per_elem must be non-negative, finite and at most \
                     {MAX_COST_CYCLES}, got {v}"
                )
            })
        }
        "density_millis" => (cfg.density_millis == 0 || cfg.density_millis > 1000).then(|| {
            format!(
                "density_millis must be in 1..=1000 (fixed-point thousandths), got {}",
                cfg.density_millis
            )
        }),
        _ => None,
    }
}

/// Every range-checked config key, in file order.
const RANGE_KEYS: [&str; 8] = [
    "array_dim",
    "dram_elems_per_cycle",
    "dram_burst_overhead",
    "dram_burst_len",
    "buf_a_half",
    "buf_b_half",
    "reorg_cycles_per_elem",
    "density_millis",
];

/// Sanity constraints on a config, however it was built (file, preset,
/// point spec, hand construction). Field predicates are shared with
/// [`parse`]'s line-numbered per-key checks.
pub fn validate(cfg: &AccelConfig) -> Result<(), ConfigError> {
    for key in RANGE_KEYS {
        if let Some(msg) = field_range_error(key, cfg) {
            return Err(ConfigError::new(msg));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_text_gives_defaults() {
        let cfg = parse("").unwrap();
        assert_eq!(cfg.array_dim, AccelConfig::default().array_dim);
    }

    #[test]
    fn full_config_round_trip() {
        let cfg = parse(
            "# edge device\n\
             array_dim = 8\n\
             dram_elems_per_cycle = 2.0\n\
             dram_burst_overhead = 12\n\
             dram_burst_len = 32\n\
             buf_a_half = 16384\n\
             buf_b_half = 16384\n\
             reorg_cycles_per_elem = 6\n\
             sparse_skip = true\n\
             lowering = cc\n\
             density_millis = 500\n",
        )
        .unwrap();
        assert_eq!(cfg.array_dim, 8);
        assert_eq!(cfg.dram.elems_per_cycle, 2.0);
        assert_eq!(cfg.dram.burst_len, 32);
        assert_eq!(cfg.buf_a_half, 16384);
        assert!(cfg.sparse_skip);
        assert_eq!(cfg.lowering, crate::sparse::SparseLowering::ColumnCombine);
        assert_eq!(cfg.density_millis, 500);
    }

    #[test]
    fn strategy_and_objective_keys_parse() {
        use crate::accel::strategy::{AutoObjective, LoweringSelect, LoweringStrategy};
        let cfg = parse("lowering_strategy = auto\nobjective = traffic\n").unwrap();
        assert_eq!(cfg.strategy, LoweringSelect::Auto);
        assert_eq!(cfg.objective, AutoObjective::Traffic);
        let cfg = parse("lowering_strategy = eco-os\n").unwrap();
        assert_eq!(cfg.strategy, LoweringSelect::Fixed(LoweringStrategy::EcoOutputStationary));
        // Defaults when the keys are absent: the paper's fixed BP-im2col
        // under the runtime objective.
        let cfg = parse("").unwrap();
        assert_eq!(cfg.strategy, LoweringSelect::default());
        assert_eq!(cfg.objective, AutoObjective::Runtime);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let cfg = parse("\n# comment\narray_dim = 4 # trailing\n\n").unwrap();
        assert_eq!(cfg.array_dim, 4);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = parse("arraydim = 16").unwrap_err();
        assert!(format!("{err:#}").contains("unknown key"));
    }

    #[test]
    fn bad_value_rejected_with_line_number() {
        let err = parse("array_dim = banana").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"));
    }

    #[test]
    fn constraints_enforced() {
        assert!(parse("array_dim = 0").is_err());
        assert!(parse("array_dim = 32").is_err()); // mask is u16
        assert!(parse("dram_elems_per_cycle = -1").is_err());
        assert!(parse("buf_a_half = 0").is_err());
    }

    #[test]
    fn load_missing_file_names_the_path() {
        let err = load("/no/such/file.cfg").unwrap_err();
        assert!(format!("{err:#}").contains("file.cfg"));
    }

    #[test]
    fn shipped_presets_parse() {
        for preset in ["configs/default.cfg", "configs/edge.cfg", "configs/hpc.cfg"] {
            let path = concat!(env!("CARGO_MANIFEST_DIR"), "/").to_string() + preset;
            load(&path).unwrap_or_else(|e| panic!("{preset}: {e:#}"));
        }
    }

    /// Every preset shipped under `configs/` must round-trip through
    /// the parser: read from disk, validate, render, re-parse, and land
    /// on the bit-identical configuration.
    #[test]
    fn every_shipped_preset_round_trips_through_render() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
        let mut presets: Vec<_> = std::fs::read_dir(dir)
            .expect("configs/ exists")
            .map(|e| e.expect("readable entry").path())
            .filter(|p| p.extension().is_some_and(|e| e == "cfg"))
            .collect();
        presets.sort();
        assert!(presets.len() >= 3, "default/edge/hpc at minimum: {presets:?}");
        for path in presets {
            let cfg = load(&path).unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            validate(&cfg).unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            let text = render(&cfg);
            let back = parse(&text).unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
            // Bit-exact round trip, float fields included.
            assert_eq!(back.array_dim, cfg.array_dim, "{}", path.display());
            assert_eq!(
                back.dram.elems_per_cycle.to_bits(),
                cfg.dram.elems_per_cycle.to_bits(),
                "{}",
                path.display()
            );
            assert_eq!(
                back.dram.burst_overhead.to_bits(),
                cfg.dram.burst_overhead.to_bits(),
                "{}",
                path.display()
            );
            assert_eq!(back.dram.burst_len, cfg.dram.burst_len, "{}", path.display());
            assert_eq!(back.buf_a_half, cfg.buf_a_half, "{}", path.display());
            assert_eq!(back.buf_b_half, cfg.buf_b_half, "{}", path.display());
            assert_eq!(
                back.reorg_cycles_per_elem.to_bits(),
                cfg.reorg_cycles_per_elem.to_bits(),
                "{}",
                path.display()
            );
            assert_eq!(back.sparse_skip, cfg.sparse_skip, "{}", path.display());
            assert_eq!(back.lowering, cfg.lowering, "{}", path.display());
            assert_eq!(back.density_millis, cfg.density_millis, "{}", path.display());
            assert_eq!(back.strategy, cfg.strategy, "{}", path.display());
            assert_eq!(back.objective, cfg.objective, "{}", path.display());
            // Rendering is idempotent.
            assert_eq!(render(&back), text, "{}", path.display());
        }
    }

    #[test]
    fn duplicate_keys_rejected_with_line_number() {
        let err = parse("array_dim = 8\nbuf_a_half = 1024\narray_dim = 16\n").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 3"), "{msg}");
        assert!(msg.contains("duplicate key"), "{msg}");
        assert!(msg.contains("array_dim"), "{msg}");
    }

    #[test]
    fn out_of_range_values_name_their_line() {
        for (text, line, needle) in [
            ("array_dim = 32", "line 1", "1..=16"),
            ("buf_a_half = 4096\narray_dim = 0", "line 2", "1..=16"),
            ("dram_elems_per_cycle = -1", "line 1", "positive"),
            ("dram_elems_per_cycle = inf", "line 1", "finite"),
            ("\n\ndram_burst_len = 0", "line 3", "1..="),
            ("dram_burst_overhead = -0.5", "line 1", "non-negative"),
            ("buf_b_half = 0", "line 1", "1..="),
            ("reorg_cycles_per_elem = nan", "line 1", "finite"),
            ("density_millis = 0", "line 1", "1..=1000"),
            ("density_millis = 1001", "line 1", "1..=1000"),
            ("lowering = nope", "line 1", "unknown sparse lowering"),
            ("lowering_strategy = nope", "line 1", "unknown lowering strategy"),
            ("objective = nope", "line 1", "unknown autotune objective"),
        ] {
            let err = parse(text).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(line), "{text:?}: {msg}");
            assert!(msg.contains(needle), "{text:?}: {msg}");
        }
    }

    #[test]
    fn validate_shares_the_parse_predicates() {
        // A config built outside the file parser (point spec, hand
        // construction) hits the same domain bounds — burst_len 0 would
        // otherwise divide by zero inside DramModel::transfer_cycles.
        let mut cfg = AccelConfig::default();
        cfg.dram.burst_len = 0;
        assert!(validate(&cfg).is_err());
        let mut cfg = AccelConfig::default();
        cfg.dram.elems_per_cycle = f64::INFINITY;
        assert!(validate(&cfg).is_err());
        let mut cfg = AccelConfig::default();
        cfg.buf_a_half = MAX_BUF_HALF + 1;
        assert!(validate(&cfg).is_err());
        let mut cfg = AccelConfig::default();
        cfg.reorg_cycles_per_elem = f64::NAN;
        assert!(validate(&cfg).is_err());
        let mut cfg = AccelConfig::default();
        cfg.density_millis = 0;
        assert!(validate(&cfg).is_err());
        validate(&AccelConfig::default()).unwrap();
    }
}
