//! Config-file loading for the accelerator (`key = value` format).
//!
//! The offline image has no serde/toml (and the default build carries no
//! external dependencies at all), so the parser and its error type are
//! hand-rolled: one `key = value` per line, `#` comments, unknown keys
//! rejected (a typo must not silently fall back to a default). See
//! `configs/*.cfg` for the shipped platform presets.

use std::fmt;
use std::path::Path;

use crate::accel::config::AccelConfig;

/// Config-parsing error: a human-readable message chain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConfigError(String);

impl ConfigError {
    fn new(msg: impl Into<String>) -> Self {
        Self(msg.into())
    }

    /// Prepend a context line (mirrors `anyhow::Context` formatting with
    /// `{:#}`: `context: cause`).
    fn context(self, ctx: impl fmt::Display) -> Self {
        Self(format!("{ctx}: {}", self.0))
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Parse an accelerator config from `key = value` text, starting from
/// the defaults.
pub fn parse(text: &str) -> Result<AccelConfig, ConfigError> {
    let mut cfg = AccelConfig::default();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(ConfigError::new(format!(
                "line {}: expected `key = value`, got {raw:?}",
                lineno + 1
            )));
        };
        let (key, value) = (key.trim(), value.trim());
        let bad = || ConfigError::new(format!("line {}: bad value for {key}: {value:?}", lineno + 1));
        match key {
            "array_dim" => cfg.array_dim = value.parse().map_err(|_| bad())?,
            "dram_elems_per_cycle" => cfg.dram.elems_per_cycle = value.parse().map_err(|_| bad())?,
            "dram_burst_overhead" => cfg.dram.burst_overhead = value.parse().map_err(|_| bad())?,
            "dram_burst_len" => cfg.dram.burst_len = value.parse().map_err(|_| bad())?,
            "buf_a_half" => cfg.buf_a_half = value.parse().map_err(|_| bad())?,
            "buf_b_half" => cfg.buf_b_half = value.parse().map_err(|_| bad())?,
            "reorg_cycles_per_elem" => cfg.reorg_cycles_per_elem = value.parse().map_err(|_| bad())?,
            "sparse_skip" => cfg.sparse_skip = value.parse().map_err(|_| bad())?,
            other => {
                return Err(ConfigError::new(format!("line {}: unknown key {other:?}", lineno + 1)))
            }
        }
    }
    validate(&cfg)?;
    Ok(cfg)
}

/// Load a config file.
pub fn load(path: impl AsRef<Path>) -> Result<AccelConfig, ConfigError> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| ConfigError::new(format!("reading {}: {e}", path.display())))?;
    parse(&text).map_err(|e| e.context(format!("parsing {}", path.display())))
}

/// Sanity constraints on a parsed config.
pub fn validate(cfg: &AccelConfig) -> Result<(), ConfigError> {
    if cfg.array_dim == 0 || cfg.array_dim > 16 {
        // compress/crossbar masks are u16 (one bit per lane).
        return Err(ConfigError::new(format!("array_dim must be in 1..=16, got {}", cfg.array_dim)));
    }
    if cfg.dram.elems_per_cycle <= 0.0 {
        return Err(ConfigError::new("dram_elems_per_cycle must be positive"));
    }
    if cfg.buf_a_half == 0 || cfg.buf_b_half == 0 {
        return Err(ConfigError::new("buffer halves must be non-empty"));
    }
    if cfg.reorg_cycles_per_elem < 0.0 {
        return Err(ConfigError::new("reorg_cycles_per_elem must be non-negative"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_text_gives_defaults() {
        let cfg = parse("").unwrap();
        assert_eq!(cfg.array_dim, AccelConfig::default().array_dim);
    }

    #[test]
    fn full_config_round_trip() {
        let cfg = parse(
            "# edge device\n\
             array_dim = 8\n\
             dram_elems_per_cycle = 2.0\n\
             dram_burst_overhead = 12\n\
             dram_burst_len = 32\n\
             buf_a_half = 16384\n\
             buf_b_half = 16384\n\
             reorg_cycles_per_elem = 6\n\
             sparse_skip = true\n",
        )
        .unwrap();
        assert_eq!(cfg.array_dim, 8);
        assert_eq!(cfg.dram.elems_per_cycle, 2.0);
        assert_eq!(cfg.dram.burst_len, 32);
        assert_eq!(cfg.buf_a_half, 16384);
        assert!(cfg.sparse_skip);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let cfg = parse("\n# comment\narray_dim = 4 # trailing\n\n").unwrap();
        assert_eq!(cfg.array_dim, 4);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = parse("arraydim = 16").unwrap_err();
        assert!(format!("{err:#}").contains("unknown key"));
    }

    #[test]
    fn bad_value_rejected_with_line_number() {
        let err = parse("array_dim = banana").unwrap_err();
        assert!(format!("{err:#}").contains("line 1"));
    }

    #[test]
    fn constraints_enforced() {
        assert!(parse("array_dim = 0").is_err());
        assert!(parse("array_dim = 32").is_err()); // mask is u16
        assert!(parse("dram_elems_per_cycle = -1").is_err());
        assert!(parse("buf_a_half = 0").is_err());
    }

    #[test]
    fn load_missing_file_names_the_path() {
        let err = load("/no/such/file.cfg").unwrap_err();
        assert!(format!("{err:#}").contains("file.cfg"));
    }

    #[test]
    fn shipped_presets_parse() {
        for preset in ["configs/default.cfg", "configs/edge.cfg", "configs/hpc.cfg"] {
            let path = concat!(env!("CARGO_MANIFEST_DIR"), "/").to_string() + preset;
            load(&path).unwrap_or_else(|e| panic!("{preset}: {e:#}"));
        }
    }
}
