//! Datapath-faithful functional execution.
//!
//! The numbers that come out of the accelerator must be *the same
//! numbers* the math produces. This module executes a backpropagation
//! pass through the actual component chain — address generation
//! (Algorithms 1/2) → NZ detection → window compression → compact fetch →
//! crossbar recovery → cycle-stepped systolic array — and is tested
//! bit-for-bit against the functional oracle. Grouped layers run their
//! `G` per-group GEMMs back to back on the same array. Intended for
//! small layers (it is register-accurate); the analytic
//! [`crate::accel::timing`] engine covers full-size layers and must
//! agree with the cycle counts measured here.

use crate::accel::tiling::{GemmShape, Tiling};
use crate::conv::ConvParams;
use crate::im2col::pipeline::{Mode, Pass};
use crate::im2col::{dilated, reorg, traditional, transposed};
use crate::sim::compress::compress_window;
use crate::sim::crossbar::expand;
use crate::sim::systolic::SystolicArray;
use crate::tensor::{Matrix, Tensor4};

/// Gather one lowered-matrix operand through the BP-im2col hardware path:
/// per 16-lane window — map addresses, compress to base+mask, fetch the
/// compact elements, re-inflate through the crossbar.
fn gather_via_datapath(
    compact: &[f32],
    rows: usize,
    cols: usize,
    t: usize,
    map: impl Fn(usize) -> Option<usize>,
) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for r in 0..rows {
        let mut c0 = 0;
        while c0 < cols {
            let width = t.min(cols - c0);
            let addrs: Vec<Option<usize>> =
                (0..width).map(|i| map(r * cols + c0 + i)).collect();
            let win = compress_window(&addrs);
            // Buffer returns exactly the non-zero elements (the hardware
            // fetches `win.runs` contiguous runs starting at `win.base`).
            let fetched: Vec<f32> =
                addrs.iter().flatten().map(|a| compact[*a]).collect();
            debug_assert_eq!(fetched.len(), win.count());
            // Crossbar re-inflates the dense lane layout per the mask.
            let lanes = expand(&fetched, win.mask, width);
            for (i, v) in lanes.iter().enumerate() {
                m[(r, c0 + i)] = *v;
            }
            c0 += width;
        }
    }
    m
}

/// Tiled GEMM on the cycle-stepped array: pads to `T` multiples,
/// accumulates partial sums across the `kb` blocks of each stripe.
/// Returns the product and the array cycles consumed.
pub fn tiled_gemm(a: &Matrix, b: &Matrix, t: usize) -> (Matrix, u64) {
    assert_eq!(a.cols, b.rows);
    let til = Tiling::new(GemmShape { m: a.rows, k: a.cols, j: b.cols }, t);
    let mut out = Matrix::zeros(a.rows, b.cols);
    let mut arr = SystolicArray::new(t);
    let mut cycles = 0u64;
    for jb in 0..til.n_j {
        for kb in 0..til.n_k {
            let b_block = Matrix::from_fn(t, t, |r, c| {
                let (bk, bj) = (kb * t + r, jb * t + c);
                if bk < b.rows && bj < b.cols {
                    b[(bk, bj)]
                } else {
                    0.0
                }
            });
            for mb in 0..til.n_m {
                let m_rows = if mb + 1 == til.n_m { til.m_last } else { t };
                let a_block = Matrix::from_fn(m_rows, t, |r, c| {
                    let (am, ak) = (mb * t + r, kb * t + c);
                    if ak < a.cols {
                        a[(am, ak)]
                    } else {
                        0.0
                    }
                });
                let (res, cyc) = arr.block_matmul(&a_block, &b_block);
                cycles += cyc;
                for r in 0..m_rows {
                    for c in 0..t {
                        let oj = jb * t + c;
                        if oj < b.cols {
                            out[(mb * t + r, oj)] += res[(r, c)];
                        }
                    }
                }
            }
        }
    }
    (out, cycles)
}

/// Loss calculation executed on the simulated accelerator.
pub fn loss_calc_on_array(
    dy: &Tensor4,
    w: &Tensor4,
    p: &ConvParams,
    mode: Mode,
    t: usize,
) -> (Tensor4, u64) {
    let shape = GemmShape::from_pass(Pass::Loss, p);
    // Every implicit strategy (BP and the EcoFlow scatters) maps the
    // same compact-tensor addresses — the dataflows differ in cycle
    // cost only, never in the math.
    let dyz = match mode {
        Mode::Traditional => Some(reorg::dilate_pad_loss(dy, p)),
        Mode::BpIm2col | Mode::EcoOutputStationary | Mode::EcoInputStationary => None,
    };
    let mut dx = Tensor4::zeros([p.b, p.c, p.hi, p.wi]);
    let mut cycles = 0u64;
    for g in 0..p.groups {
        let a = traditional::lower_loss_a(w, p, g);
        let b = match &dyz {
            Some(z) => traditional::lower_loss_b(z, p, g),
            None => gather_via_datapath(&dy.data, shape.k, shape.j, t, |addr| {
                transposed::map_addr(addr, p, g)
            }),
        };
        let (out, cyc) = tiled_gemm(&a, &b, t);
        cycles += cyc;
        traditional::loss_from_gemm_group(&out, p, g, &mut dx);
    }
    (dx, cycles)
}

/// Gradient calculation executed on the simulated accelerator.
pub fn grad_calc_on_array(
    x: &Tensor4,
    dy: &Tensor4,
    p: &ConvParams,
    mode: Mode,
    t: usize,
) -> (Tensor4, u64) {
    let shape = GemmShape::from_pass(Pass::Grad, p);
    let dyd = match mode {
        Mode::Traditional => Some(reorg::dilate_loss(dy, p)),
        Mode::BpIm2col | Mode::EcoOutputStationary | Mode::EcoInputStationary => None,
    };
    let xpad = reorg::pad_input(x, p);
    let mut dw = Tensor4::zeros([p.n, p.cg(), p.kh, p.kw]);
    let mut cycles = 0u64;
    for g in 0..p.groups {
        let a = match &dyd {
            Some(z) => traditional::lower_grad_a(z, p, g),
            None => gather_via_datapath(&dy.data, shape.m, shape.k, t, |addr| {
                dilated::map_addr(addr, p, g)
            }),
        };
        let b = traditional::lower_grad_b(&xpad, p, g);
        let (out, cyc) = tiled_gemm(&a, &b, t);
        cycles += cyc;
        traditional::grad_from_gemm_group(&out, p, g, &mut dw);
    }
    (dw, cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::timing::simulate_pass;
    use crate::accel::AccelConfig;
    use crate::conv::{conv2d_bwd_input, conv2d_bwd_weight};
    use crate::tensor::Rng;

    fn tensors(p: &ConvParams, seed: u64) -> (Tensor4, Tensor4, Tensor4) {
        let mut rng = Rng::new(seed);
        let x = Tensor4::random([p.b, p.c, p.hi, p.wi], &mut rng);
        let w = Tensor4::random([p.n, p.cg(), p.kh, p.kw], &mut rng);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        (x, w, dy)
    }

    #[test]
    fn tiled_gemm_matches_reference() {
        let mut rng = Rng::new(60);
        let a = Matrix::from_fn(19, 37, |_, _| rng.range_f32(-1.0, 1.0));
        let b = Matrix::from_fn(37, 23, |_, _| rng.range_f32(-1.0, 1.0));
        let (out, _) = tiled_gemm(&a, &b, 8);
        assert!(out.max_abs_diff(&a.matmul(&b)) < 1e-4);
    }

    #[test]
    fn array_loss_matches_oracle_both_modes() {
        let p = ConvParams::basic(1, 2, 9, 9, 2, 3, 3, 2, 1, 1);
        let (_, w, dy) = tensors(&p, 61);
        let oracle = conv2d_bwd_input(&dy, &w, &p);
        for mode in Mode::ALL {
            let (dx, _) = loss_calc_on_array(&dy, &w, &p, mode, 8);
            assert!(dx.max_abs_diff(&oracle) < 1e-4, "{mode:?}");
        }
    }

    #[test]
    fn array_grad_matches_oracle_both_modes() {
        let p = ConvParams::basic(1, 2, 9, 9, 2, 3, 3, 2, 1, 1);
        let (x, _, dy) = tensors(&p, 62);
        let oracle = conv2d_bwd_weight(&x, &dy, &p);
        for mode in Mode::ALL {
            let (dw, _) = grad_calc_on_array(&x, &dy, &p, mode, 8);
            assert!(dw.max_abs_diff(&oracle) < 1e-3, "{mode:?}");
        }
    }

    #[test]
    fn array_matches_oracle_generalized_geometries() {
        for (i, p) in [
            ConvParams::basic(1, 2, 9, 12, 2, 3, 3, 1, 1, 1).with_stride(2, 3),
            ConvParams::basic(1, 2, 11, 11, 2, 3, 3, 1, 2, 2).with_dilation(2, 2),
            ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(2),
            ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(4),
        ]
        .into_iter()
        .enumerate()
        {
            let (x, w, dy) = tensors(&p, 90 + i as u64);
            let dx_oracle = conv2d_bwd_input(&dy, &w, &p);
            let dw_oracle = conv2d_bwd_weight(&x, &dy, &p);
            for mode in Mode::ALL {
                let (dx, _) = loss_calc_on_array(&dy, &w, &p, mode, 8);
                assert!(dx.max_abs_diff(&dx_oracle) < 2e-4, "{mode:?} dX {}", p.id());
                let (dw, _) = grad_calc_on_array(&x, &dy, &p, mode, 8);
                assert!(dw.max_abs_diff(&dw_oracle) < 2e-3, "{mode:?} dW {}", p.id());
            }
        }
    }

    #[test]
    fn array_modes_agree_bitwise() {
        let p = ConvParams::basic(1, 1, 10, 10, 2, 3, 3, 2, 0, 0);
        let (x, w, dy) = tensors(&p, 63);
        let (dx_t, _) = loss_calc_on_array(&dy, &w, &p, Mode::Traditional, 8);
        let (dx_b, _) = loss_calc_on_array(&dy, &w, &p, Mode::BpIm2col, 8);
        assert_eq!(dx_t, dx_b);
        let (dw_t, _) = grad_calc_on_array(&x, &dy, &p, Mode::Traditional, 8);
        let (dw_b, _) = grad_calc_on_array(&x, &dy, &p, Mode::BpIm2col, 8);
        assert_eq!(dw_t, dw_b);
    }

    #[test]
    fn cycle_stepped_agrees_with_analytic_compute() {
        // The register-accurate array must pay exactly the cycles the
        // analytic timing model charges as compute.
        let p = ConvParams::basic(1, 2, 9, 9, 2, 3, 3, 2, 1, 1);
        let (x, w, dy) = tensors(&p, 64);
        let cfg = AccelConfig { array_dim: 8, ..AccelConfig::default() };
        for mode in Mode::ALL {
            let (_, c_loss) = loss_calc_on_array(&dy, &w, &p, mode, 8);
            let m_loss = simulate_pass(Pass::Loss, mode, &p, &cfg);
            assert_eq!(c_loss as f64, m_loss.compute_cycles, "{mode:?} loss");
            let (_, c_grad) = grad_calc_on_array(&x, &dy, &p, mode, 8);
            let m_grad = simulate_pass(Pass::Grad, mode, &p, &cfg);
            assert_eq!(c_grad as f64, m_grad.compute_cycles, "{mode:?} grad");
        }
    }

    #[test]
    fn cycle_stepped_agrees_with_analytic_compute_grouped() {
        // Same consistency on a grouped layer: G per-group GEMMs.
        let p = ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(2);
        let (x, w, dy) = tensors(&p, 65);
        let cfg = AccelConfig { array_dim: 8, ..AccelConfig::default() };
        for mode in Mode::ALL {
            let (_, c_loss) = loss_calc_on_array(&dy, &w, &p, mode, 8);
            let m_loss = simulate_pass(Pass::Loss, mode, &p, &cfg);
            assert_eq!(c_loss as f64, m_loss.compute_cycles, "{mode:?} loss");
            let (_, c_grad) = grad_calc_on_array(&x, &dy, &p, mode, 8);
            let m_grad = simulate_pass(Pass::Grad, mode, &p, &cfg);
            assert_eq!(c_grad as f64, m_grad.compute_cycles, "{mode:?} grad");
        }
    }

    #[test]
    fn datapath_gather_equals_direct_gather() {
        // compress -> fetch -> crossbar must reproduce the plain gather.
        let p = ConvParams::basic(1, 1, 8, 8, 2, 3, 3, 2, 1, 1);
        let (_, _, dy) = tensors(&p, 66);
        let shape = GemmShape::from_pass(Pass::Loss, &p);
        let via_hw = gather_via_datapath(&dy.data, shape.k, shape.j, 16, |a| {
            transposed::map_addr(a, &p, 0)
        });
        assert_eq!(via_hw, transposed::gather_matrix(&dy, &p, 0));
    }
}
