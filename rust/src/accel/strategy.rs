//! Lowering strategies — the algorithm axis of the planner (DESIGN.md
//! §15).
//!
//! The seed modeled exactly two lowerings behind
//! `im2col::pipeline::Mode` (traditional explicit im2col vs the paper's
//! implicit BP-im2col) and match-dispatched on it inside the plan
//! builder. This module promotes the lowering to a first-class
//! [`LoweringStrategy`] family the planner is *parametric* over:
//!
//! * [`LoweringStrategy::Traditional`] — explicit im2col: materialize
//!   the zero-spaced tensors off-chip (reorganization), stream them
//!   densely.
//! * [`LoweringStrategy::BpIm2col`] — the paper's implicit gather:
//!   address-map into the compact tensors, detect zeros arithmetically.
//! * [`LoweringStrategy::EcoOutputStationary`] /
//!   [`LoweringStrategy::EcoInputStationary`] — EcoFlow-style dataflows
//!   (arXiv 2202.02310): instead of *gathering* dilated/transposed
//!   windows (and streaming the re-inflated zeros through the array),
//!   keep one operand stationary and **scatter partial sums** into an
//!   output accumulator, so the zero-space never enters the datapath at
//!   all. The win is compute that scales with the *non-zero* fraction;
//!   the price is a scatter-serialization factor, an output-accumulator
//!   buffer term, lost operand reuse (OS) or partial-sum round trips
//!   (IS), and a deeper address-generation prologue.
//!
//! [`LoweringSelect`] adds the planner-facing `Auto` choice: build all
//! candidate plans per `(layer, pass, config)`, score them under a
//! configurable [`AutoObjective`], pick the minimum deterministically
//! (strict `<`, so ties resolve to the earliest entry of
//! [`LoweringStrategy::STRATEGIES`] — stable across threads, devices
//! and frontends).

use crate::accel::metrics::PassMetrics;
use crate::conv::ConvParams;

/// One lowering algorithm the planner can lower a backprop pass with.
///
/// Re-exported as `im2col::pipeline::Mode` for backward compatibility —
/// the paper-era two-variant enum is the `Traditional`/`BpIm2col`
/// prefix of this family ([`LoweringStrategy::ALL`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LoweringStrategy {
    /// Traditional explicit im2col: reorganize (materialize the
    /// zero-spaces off-chip), then dense explicit lowering.
    Traditional,
    /// BP-im2col: implicit gather straight from the compact tensors
    /// (the paper's design).
    BpIm2col,
    /// EcoFlow-style output-stationary scatter: outputs accumulate in
    /// place, the zero-spaced *stationary* operand is never inflated.
    /// Wins the transposed-convolution loss pass of strided layers;
    /// pays a re-streamed stationary operand and an output-accumulator
    /// stripe.
    EcoOutputStationary,
    /// EcoFlow-style input-stationary scatter: the compact loss map
    /// stays resident, partial sums round-trip through the accumulator.
    /// Wins the dilated-convolution gradient pass of strided layers.
    EcoInputStationary,
}

impl LoweringStrategy {
    /// The paper's two modes, baseline first (matches the paper's
    /// legends and every Table II/III comparison). Kept at two entries
    /// on purpose: `Mode::ALL` loops throughout the crate reproduce the
    /// paper's two-column artifacts bit-identically.
    pub const ALL: [LoweringStrategy; 2] =
        [LoweringStrategy::Traditional, LoweringStrategy::BpIm2col];

    /// Every strategy, in the stable autotune tie-break order. The
    /// autotuner scores candidates in this order and keeps the first
    /// strict minimum, so a tie between BP-im2col and an EcoFlow
    /// variant (their closed forms coincide on layers without a
    /// zero-space) deterministically resolves to BP-im2col.
    pub const STRATEGIES: [LoweringStrategy; 4] = [
        LoweringStrategy::Traditional,
        LoweringStrategy::BpIm2col,
        LoweringStrategy::EcoOutputStationary,
        LoweringStrategy::EcoInputStationary,
    ];

    /// Stable lowercase name (CLI/wire form and the mix-summary key).
    pub const fn name(self) -> &'static str {
        match self {
            LoweringStrategy::Traditional => "trad",
            LoweringStrategy::BpIm2col => "bp",
            LoweringStrategy::EcoOutputStationary => "eco-os",
            LoweringStrategy::EcoInputStationary => "eco-is",
        }
    }

    /// Legend / table label (the paper's names for its two modes).
    pub const fn legend(self) -> &'static str {
        match self {
            LoweringStrategy::Traditional => "Original",
            LoweringStrategy::BpIm2col => "Ours",
            LoweringStrategy::EcoOutputStationary => "EcoFlow-OS",
            LoweringStrategy::EcoInputStationary => "EcoFlow-IS",
        }
    }

    /// Integer wire/axis code (the DSE `lowering_strategy` axis value).
    pub const fn code(self) -> u8 {
        match self {
            LoweringStrategy::Traditional => 0,
            LoweringStrategy::BpIm2col => 1,
            LoweringStrategy::EcoOutputStationary => 2,
            LoweringStrategy::EcoInputStationary => 3,
        }
    }

    /// Inverse of [`LoweringStrategy::code`].
    pub fn from_code(code: u64) -> Result<Self, String> {
        match code {
            0 => Ok(LoweringStrategy::Traditional),
            1 => Ok(LoweringStrategy::BpIm2col),
            2 => Ok(LoweringStrategy::EcoOutputStationary),
            3 => Ok(LoweringStrategy::EcoInputStationary),
            other => Err(format!("lowering strategy code must be 0..=3, got {other}")),
        }
    }

    /// Parse a CLI/config spelling; strict.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "trad" => Ok(LoweringStrategy::Traditional),
            "bp" => Ok(LoweringStrategy::BpIm2col),
            "eco-os" => Ok(LoweringStrategy::EcoOutputStationary),
            "eco-is" => Ok(LoweringStrategy::EcoInputStationary),
            other => Err(format!(
                "unknown lowering strategy {other:?} (supported: trad, bp, eco-os, eco-is)"
            )),
        }
    }

    /// True for the strategies that lower implicitly from the compact
    /// tensors (everything except the explicit baseline) — no
    /// reorganization pass, no zero-spaced DRAM copy.
    pub const fn is_implicit(self) -> bool {
        !matches!(self, LoweringStrategy::Traditional)
    }

    /// The strategy whose closed forms this layer actually executes —
    /// the calibration normalization of DESIGN.md §15.
    ///
    /// The EcoFlow scatter pipeline only differs from BP-im2col where
    /// backpropagation injects a zero-space (forward stride > 1) or
    /// scattered kernel taps (dilation > 1): on stride-1 undilated
    /// layers the scatter degenerates to the same compact stream and
    /// the closed forms coincide, so we normalize to BP-im2col and the
    /// coincidence is *bit-exact* rather than merely close. Grouped
    /// layers also normalize: the scatter index datapath addresses one
    /// accumulator stripe and cannot compose the per-group channel
    /// base, so each group would need its own pass — modeled as the
    /// BP gather pipeline instead.
    pub fn effective(self, p: &ConvParams) -> Self {
        match self {
            LoweringStrategy::Traditional | LoweringStrategy::BpIm2col => self,
            LoweringStrategy::EcoOutputStationary | LoweringStrategy::EcoInputStationary => {
                let scattered = p.sh > 1 || p.sw > 1 || p.dh > 1 || p.dw > 1;
                if scattered && p.groups == 1 {
                    self
                } else {
                    LoweringStrategy::BpIm2col
                }
            }
        }
    }
}

/// How the planner chooses the [`LoweringStrategy`] of each pass: a
/// fixed strategy for every layer, or the per-layer autotuner.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LoweringSelect {
    /// Lower every layer/pass with the same strategy.
    Fixed(LoweringStrategy),
    /// Score every strategy per `(layer, pass, config)` under the
    /// config's [`AutoObjective`] and pick the minimum (tie-break by
    /// [`LoweringStrategy::STRATEGIES`] order).
    Auto,
}

impl Default for LoweringSelect {
    /// The paper's design: BP-im2col everywhere.
    fn default() -> Self {
        LoweringSelect::Fixed(LoweringStrategy::BpIm2col)
    }
}

impl LoweringSelect {
    /// Wire code past the fixed strategies.
    const AUTO_CODE: u64 = LoweringStrategy::STRATEGIES.len() as u64;

    /// Stable lowercase name (CLI/config/wire form).
    pub const fn name(self) -> &'static str {
        match self {
            LoweringSelect::Fixed(s) => s.name(),
            LoweringSelect::Auto => "auto",
        }
    }

    /// Integer wire/axis code: the fixed strategy's code, or 4 for
    /// `auto` (the DSE `lowering_strategy` axis value).
    pub const fn code(self) -> u64 {
        match self {
            LoweringSelect::Fixed(s) => s.code() as u64,
            LoweringSelect::Auto => Self::AUTO_CODE,
        }
    }

    /// Inverse of [`LoweringSelect::code`].
    pub fn from_code(code: u64) -> Result<Self, String> {
        if code == Self::AUTO_CODE {
            return Ok(LoweringSelect::Auto);
        }
        LoweringStrategy::from_code(code)
            .map(LoweringSelect::Fixed)
            .map_err(|_| format!("lowering select code must be 0..=4, got {code}"))
    }

    /// Parse a CLI/config spelling; strict.
    pub fn parse(s: &str) -> Result<Self, String> {
        if s == "auto" {
            return Ok(LoweringSelect::Auto);
        }
        LoweringStrategy::parse(s).map(LoweringSelect::Fixed).map_err(|_| {
            format!("unknown lowering strategy {s:?} (supported: trad, bp, eco-os, eco-is, auto)")
        })
    }
}

/// The cost function the autotuner minimizes per `(layer, pass)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum AutoObjective {
    /// End-to-end pass runtime in cycles (the default).
    #[default]
    Runtime,
    /// Total off-chip traffic in bytes.
    Traffic,
    /// On-chip buffer reads toward the array (A + B).
    Reads,
}

impl AutoObjective {
    /// All objectives, in wire order.
    pub const ALL: [AutoObjective; 3] =
        [AutoObjective::Runtime, AutoObjective::Traffic, AutoObjective::Reads];

    /// Stable lowercase name (config/wire form).
    pub const fn name(self) -> &'static str {
        match self {
            AutoObjective::Runtime => "runtime",
            AutoObjective::Traffic => "traffic",
            AutoObjective::Reads => "reads",
        }
    }

    /// Unit of [`AutoObjective::cost`], for artifact columns.
    pub const fn unit(self) -> &'static str {
        match self {
            AutoObjective::Runtime => "cycles",
            AutoObjective::Traffic => "bytes",
            AutoObjective::Reads => "reads",
        }
    }

    /// Parse a CLI/config spelling; strict.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "runtime" => Ok(AutoObjective::Runtime),
            "traffic" => Ok(AutoObjective::Traffic),
            "reads" => Ok(AutoObjective::Reads),
            other => Err(format!(
                "unknown autotune objective {other:?} (supported: runtime, traffic, reads)"
            )),
        }
    }

    /// Scalar cost of one pass under this objective. Counters convert
    /// through `u64 -> f64` exactly (all honest values are far below
    /// 2^53), so comparisons are bit-deterministic.
    pub fn cost(self, m: &PassMetrics) -> f64 {
        match self {
            AutoObjective::Runtime => m.total_cycles(),
            AutoObjective::Traffic => m.traffic.total() as f64,
            AutoObjective::Reads => (m.buffer_a_reads + m.buffer_b_reads) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_names_round_trip() {
        for s in LoweringStrategy::STRATEGIES {
            assert_eq!(LoweringStrategy::from_code(s.code() as u64).unwrap(), s);
            assert_eq!(LoweringStrategy::parse(s.name()).unwrap(), s);
            let select = LoweringSelect::from_code(s.code() as u64).unwrap();
            assert_eq!(select, LoweringSelect::Fixed(s));
            assert_eq!(LoweringSelect::parse(s.name()).unwrap(), LoweringSelect::Fixed(s));
        }
        assert_eq!(LoweringSelect::from_code(4).unwrap(), LoweringSelect::Auto);
        assert_eq!(LoweringSelect::parse("auto").unwrap(), LoweringSelect::Auto);
        assert!(LoweringStrategy::from_code(4).is_err());
        assert!(LoweringSelect::from_code(5).is_err());
        assert!(LoweringStrategy::parse("BP").is_err(), "names are case-sensitive");
        assert!(LoweringSelect::parse("").is_err());
        for o in AutoObjective::ALL {
            assert_eq!(AutoObjective::parse(o.name()).unwrap(), o);
        }
        assert!(AutoObjective::parse("latency").is_err());
    }

    #[test]
    fn legacy_all_is_the_paper_prefix() {
        // Mode::ALL loops all over the crate regenerate the paper's
        // two-column artifacts; the prefix must never change.
        assert_eq!(LoweringStrategy::ALL.len(), 2);
        assert_eq!(LoweringStrategy::ALL[0], LoweringStrategy::Traditional);
        assert_eq!(LoweringStrategy::ALL[1], LoweringStrategy::BpIm2col);
        assert_eq!(LoweringStrategy::STRATEGIES[..2], LoweringStrategy::ALL);
    }

    #[test]
    fn defaults_match_the_paper() {
        assert_eq!(LoweringSelect::default(), LoweringSelect::Fixed(LoweringStrategy::BpIm2col));
        assert_eq!(AutoObjective::default(), AutoObjective::Runtime);
    }

    #[test]
    fn eco_normalizes_where_closed_forms_coincide() {
        use LoweringStrategy::*;
        let strided = ConvParams::square(56, 128, 128, 3, 2, 1);
        let stride1 = ConvParams::square(56, 128, 128, 3, 1, 1);
        let dilated = ConvParams::square(28, 256, 256, 3, 1, 2).with_dilation(2, 2);
        let grouped = ConvParams::square(56, 128, 128, 3, 2, 1).with_groups(32);
        for eco in [EcoOutputStationary, EcoInputStationary] {
            assert_eq!(eco.effective(&strided), eco, "stride-2 keeps the scatter form");
            assert_eq!(eco.effective(&dilated), eco, "dilation keeps the scatter form");
            assert_eq!(eco.effective(&stride1), BpIm2col, "stride-1 undilated normalizes");
            assert_eq!(eco.effective(&grouped), BpIm2col, "groups normalize");
        }
        // The paper's two modes are already normal forms.
        for s in LoweringStrategy::ALL {
            for p in [&strided, &stride1, &dilated, &grouped] {
                assert_eq!(s.effective(p), s);
            }
        }
    }
}
