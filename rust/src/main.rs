//! `repro` — CLI of the BP-im2col reproduction.
//!
//! A thin, declarative shell over the [`bp_im2col::api`] facade: argv is
//! parsed against a per-command option table into a
//! [`SimRequest`] (or several, for `all`), served by one
//! [`Service`], and the resulting [`Artifact`]s are printed by the
//! shared renderer — text by default, `--csv` or `--json` on every
//! command. The only command that bypasses the facade is `train`, which
//! is a PJRT *action*, not a model query.
//!
//! The offline image has no clap; parsing is hand-rolled but strict:
//! unknown options and flag-shaped values (`--config --csv`) are
//! rejected instead of silently ignored or swallowed.

use std::process::ExitCode;

use bp_im2col::accel::AccelConfig;
use bp_im2col::api::{
    render_all_csv, render_all_json, render_all_text, Artifact, DseRequest, DseWorkloads,
    FigureRequest, FleetRequest, Service, SimRequest,
};
use bp_im2col::conv::ConvParams;
#[cfg(feature = "pjrt")]
use bp_im2col::coordinator::{TrainConfig, Trainer};
use bp_im2col::im2col::pipeline::Pass;
use bp_im2col::report::Figure;
#[cfg(feature = "pjrt")]
use bp_im2col::runtime::Runtime;

const USAGE: &str = "\
repro — BP-Im2col reproduction (Yang et al., 2022)

USAGE: repro <COMMAND> [OPTIONS]

COMMANDS:
  table2                Runtime of Table II's five layers, both passes
  table3                Prologue latency of the address-gen modules
  table4                Area of the address-gen modules (ASAP7 model)
  fig6                  Backprop runtime per network (loss+grad)
  fig7                  Off-chip bandwidth per network
  fig8                  On-chip buffer bandwidth + sparsity per network
  sparsity              Lowered-matrix sparsity of every workload layer
  storage               Additional-storage overhead per network
  sparse                Sparse lowerings compared (dense vs column
                        combining vs SPOTS) over the pruned workload
                        networks, with vs-dense ratio columns
  sim --layer <SPEC>    Simulate one layer in both modes (spec below)
  traincost             Full training-step cost (fwd+loss+grad) per network
  fleet                 Backward-pass sharding across N simulated
                        accelerators (makespan, efficiency, plan cache)
  dse                   Design-space exploration: search the AccelConfig
                        space (array size, bandwidth, burst shape,
                        buffers, reorg cost, sparse skip) for
                        Pareto-optimal backprop platforms. Exhaustive
                        within --budget, seeded sampling + hill-climb
                        refinement beyond it; rows carry reproducible
                        point specs
                        (t16/e16/o8/l64/a32768/b32768/r4/s0/d1/p0/y1)
  autotune              Per-layer lowering-strategy autotuner: score
                        every workload layer x pass under every strategy
                        (trad, bp, eco-os, eco-is), record the winner
                        per row plus the strategy mix and the win margin
                        over the best single fixed strategy. --devices N
                        cross-checks that an N-device fleet inherits the
                        same choices bit-identically
  trace                 Deterministic virtual-time execution timeline:
                        replay every workload network on the canonical
                        4-device fleet and list one span per (layer,
                        pass) job — strategy, start, duration, cost
                        components, steals. Bytes are identical across
                        runs and frontends; --out FILE additionally
                        writes Chrome trace-event JSON for Perfetto /
                        chrome://tracing. --devices N cross-checks the
                        totals at another fleet width without touching
                        the output
  profile               Wall-clock host profile of the plan-build and
                        DSE hot paths: cold-build every layer geometry
                        under every strategy, price the autotuner, run
                        a small DSE search, and report per-phase calls,
                        time shares and throughput (plan builds/sec,
                        DSE points/sec). Telemetry — values vary run to
                        run and are never cached
  serve                 Long-running HTTP/1.1 JSON server over the query
                        facade: POST /v1/query, POST /v1/batch,
                        GET /v1/requests, GET /healthz, GET /metrics,
                        POST /v1/shutdown (graceful). One shared plan
                        cache + rendered-response cache per process.
  train [--steps N]     End-to-end training via the AOT HLO artifacts.
                        NOTE: requires the `pjrt` build feature — uncomment
                        the xla/anyhow [dependencies] in rust/Cargo.toml and
                        build with `--features pjrt`
  all                   Every table and figure, in order
  lint [PATHS..]        Determinism & concurrency static analysis over
                        the crate's own sources (six deny-by-default
                        rules; DESIGN.md §12). PATHS are files or
                        directories; default roots are src, tests,
                        benches and examples. Renders the findings
                        through the artifact layer and exits nonzero
                        if any finding is unsuppressed

LAYER SPEC (sim --layer):
  H/C/N/K/S/P[/G[/D]]   H input size, C in-channels, N out-channels,
                        K kernel, S stride, P padding — the paper's
                        Hi(Wi)/C/N/Kh(Kw)/S/Ph(Pw) notation. Optional:
                        G channel groups, D kernel dilation. S and D also
                        accept asymmetric `HxW` forms (e.g. S=2x1), and
                        G/D may be tagged in any order as `gG` / `dD`.
                        Value densities ride the same spec as `wM` /
                        `aM` tags in thousandths non-zero (weight /
                        activation; default 1000 = dense).
  examples:
    repro sim --layer 224/3/64/3/2/0          (Table II row 1)
    repro sim --layer 56/128/128/3/2/1/g32    (ResNeXt-style, 32 groups)
    repro sim --layer 28/256/256/3/1/2/d2     (DeepLab-style, dilation 2)
    repro sim --layer 56/64/64/3/2x1/1        (asymmetric stride)
    repro sim --layer 224/3/64/3/2/0/w250/a600 --lowering spots
                                              (75% pruned weights, 40%
                                               ReLU zeros, SPOTS core)

OPTIONS:
  --config <file.cfg>         Platform preset (see configs/)
  --bandwidth <elems/cycle>   Off-chip bandwidth override (default 16)
  --csv                       Emit CSV (several artifacts are separated
                              by `# <name>` comment lines)
  --json                      Emit one JSON document: {\"artifacts\":[...]}
  --pass loss|grad            Restrict fig6/7/8 to one pass
  --extended                  Include the dilated/grouped workload networks
  --devices N                 Shard fig6/7/8/traincost/fleet backward
                              passes across N simulated accelerators
                              (fleet default 4; totals are bit-identical
                              for any N, the fleet summary artifact shows
                              the scaling in every output format). On
                              autotune/trace: fleet cross-check only,
                              the artifact bytes never change
  --lowering-strategy S       Lowering strategy the platform runs:
                              trad|bp|eco-os|eco-is|auto (default bp;
                              auto picks per layer+pass under the
                              config's objective). The eco-* EcoFlow
                              dataflows normalize to bp where their
                              closed forms coincide (stride 1, no
                              dilation)
  --objective O               Autotune scoring objective:
                              runtime|traffic|reads (autotune; default
                              runtime)
  --steps N                   Training steps (train; default 300)
  --seed N                    Sampling seed (dse; default 0) / training
                              seed (train; default 0)
  --budget N                  Max design points to evaluate (dse;
                              default 64, cap 1024)
  --axis KEY=RANGE            Override one dse search axis (repeatable).
                              KEY: array_dim, elems_per_cycle,
                              burst_overhead, burst_len, buf_a_half,
                              buf_b_half, reorg_cycles_per_elem,
                              sparse_skip, density, lowering,
                              lowering_strategy. RANGE: a
                              single value V or LO:HI:STEP
                              (elems_per_cycle, burst_overhead,
                              reorg_cycles_per_elem and density accept
                              fractional values; lowering is the code
                              0=dense 1=cc 2=spots; lowering_strategy
                              is 0=trad 1=bp 2=eco-os 3=eco-is
                              4=auto), e.g.
                              --axis elems_per_cycle=0.5:4:0.5
                              --axis density=0.25:1:0.25 --axis lowering=0:2:1
  --layer SPEC                Layer geometry (sim: required; dse: score
                              candidates on one layer instead of the
                              paper networks)
  --lowering dense|cc|spots   Sparse lowering the platform runs (sim;
                              `column-combine` is accepted for cc;
                              default dense)
  --density F                 Config-level density scale in (0, 1],
                              composed multiplicatively with the layer's
                              own w/a density tags (sim; default 1)
  --addr HOST:PORT            Bind address (serve; default 127.0.0.1:8000,
                              port 0 picks an ephemeral port)
  --threads N                 Connection worker threads (serve; default:
                              one per core, capped at 8)
  --frontend event|pool       Serving core (serve; default event): the
                              nonblocking event loop with overload
                              shedding, or the legacy blocking pool
  --max-conns N               Event loop only: connection cap; further
                              connections are answered 429 (serve;
                              default 1024)
  --shed-queue N              Event loop only: dispatches allowed beyond
                              busy workers before requests are shed
                              with 429 + Retry-After (serve; default
                              2 x threads)
  --out <FILE>                Also write the timeline as Chrome
                              trace-event JSON — load it in Perfetto or
                              chrome://tracing (trace only; the regular
                              artifact still renders to stdout)

Unknown options are errors; `--key` options require a value that does
not itself start with `--`.
";

/// Options every command accepts.
const UNIVERSAL_OPTS: [&str; 5] =
    ["--config", "--bandwidth", "--lowering-strategy", "--csv", "--json"];

/// Options that consume a value (everything else is a bare flag).
const VALUE_OPTS: [&str; 19] = [
    "--config",
    "--bandwidth",
    "--lowering-strategy",
    "--objective",
    "--pass",
    "--devices",
    "--layer",
    "--lowering",
    "--density",
    "--steps",
    "--seed",
    "--addr",
    "--threads",
    "--budget",
    "--axis",
    "--frontend",
    "--max-conns",
    "--shed-queue",
    "--out",
];

/// Options that may appear more than once (`--axis` stacks one override
/// per search axis); everything else still rejects duplicates.
const REPEATABLE_OPTS: [&str; 1] = ["--axis"];

/// One CLI command: its name, the options it accepts beyond the
/// universal set, and whether the universal query options (config /
/// bandwidth / output format) apply at all. The whole grammar is this
/// table.
struct CommandSpec {
    name: &'static str,
    extra_opts: &'static [&'static str],
    /// `false` for `train`, the one non-query action: it neither
    /// renders artifacts nor simulates under a config, so accepting
    /// `--json`/`--csv`/`--config`/`--bandwidth` would silently ignore
    /// them — exactly the footgun this parser exists to remove.
    universal: bool,
    /// Whether bare (non-`--`) arguments are accepted. Only `lint`
    /// takes positional paths; everywhere else a stray positional is
    /// still a hard error.
    positionals: bool,
}

/// Options shared by the figure commands (and `all`, which runs them).
const FIG_OPTS: &[&str] = &["--pass", "--extended", "--devices"];

/// Shorthand for the common query-command shape (no positionals).
const fn cmd(name: &'static str, extra_opts: &'static [&'static str]) -> CommandSpec {
    CommandSpec { name, extra_opts, universal: true, positionals: false }
}

const COMMANDS: [CommandSpec; 20] = [
    cmd("table2", &[]),
    cmd("table3", &[]),
    cmd("table4", &[]),
    cmd("fig6", FIG_OPTS),
    cmd("fig7", FIG_OPTS),
    cmd("fig8", FIG_OPTS),
    cmd("sparsity", &["--extended"]),
    cmd("storage", &["--extended"]),
    cmd("sparse", &["--extended"]),
    cmd("sim", &["--layer", "--lowering", "--density"]),
    cmd("traincost", &["--devices"]),
    cmd("fleet", &["--devices", "--extended"]),
    cmd("dse", &["--budget", "--seed", "--axis", "--extended", "--layer", "--devices"]),
    cmd("autotune", &["--extended", "--devices", "--objective"]),
    cmd("trace", &["--extended", "--devices", "--out"]),
    cmd("profile", &[]),
    // `serve` is an action, not a one-shot query: it renders nothing, so
    // `--csv`/`--json` are rejected like `train`'s — but it *does*
    // simulate under a platform config, so `--config`/`--bandwidth`
    // come back in via extra_opts.
    CommandSpec {
        name: "serve",
        extra_opts: &[
            "--addr",
            "--threads",
            "--frontend",
            "--max-conns",
            "--shed-queue",
            "--config",
            "--bandwidth",
            "--lowering-strategy",
        ],
        universal: false,
        positionals: false,
    },
    CommandSpec {
        name: "train",
        extra_opts: &["--steps", "--seed"],
        universal: false,
        positionals: false,
    },
    cmd("all", FIG_OPTS),
    // `lint` analyzes sources, not the model: no platform config, no
    // CSV; its positional arguments are the paths to scan.
    CommandSpec { name: "lint", extra_opts: &["--json"], universal: false, positionals: true },
];

/// Strictly parsed options: `--key value` pairs and bare flags, each
/// checked against the command's option table at parse time.
struct Opts {
    values: Vec<(String, String)>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Opts {
    /// Scan `args` against the allowed option set. Rejects unknown
    /// options, duplicate options, missing values, flag-shaped values
    /// and — unless the command declares them — positional arguments.
    fn parse(args: &[String], spec: &CommandSpec) -> Result<Self, String> {
        let universal: &[&str] = if spec.universal { &UNIVERSAL_OPTS } else { &[] };
        let allowed: Vec<&str> = universal.iter().chain(spec.extra_opts).copied().collect();
        let mut values = Vec::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if !arg.starts_with("--") {
                if spec.positionals {
                    positionals.push(arg.clone());
                    i += 1;
                    continue;
                }
                return Err(format!(
                    "unexpected argument {arg:?} (options start with --; see `repro help`)"
                ));
            }
            if !allowed.contains(&arg.as_str()) {
                return Err(format!(
                    "unknown option {arg:?} for `{}` (supported: {})",
                    spec.name,
                    allowed.join(", ")
                ));
            }
            let repeatable = REPEATABLE_OPTS.contains(&arg.as_str());
            let seen =
                flags.iter().any(|f| f == arg) || values.iter().any(|(k, _)| k == arg);
            if seen && !repeatable {
                return Err(format!("duplicate option {arg:?}"));
            }
            if VALUE_OPTS.contains(&arg.as_str()) {
                let Some(v) = args.get(i + 1) else {
                    return Err(format!("option {arg} needs a value"));
                };
                if v.starts_with("--") {
                    return Err(format!(
                        "option {arg} needs a value, but got the option-like {v:?}"
                    ));
                }
                values.push((arg.clone(), v.clone()));
                i += 2;
            } else {
                flags.push(arg.clone());
                i += 1;
            }
        }
        Ok(Opts { values, flags, positionals })
    }

    fn value(&self, key: &str) -> Option<&str> {
        self.values.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Every value of a repeatable option, in argv order.
    fn values_all(&self, key: &str) -> Vec<&str> {
        self.values.iter().filter(|(k, _)| k == key).map(|(_, v)| v.as_str()).collect()
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Output format selected by `--csv` / `--json` (mutually exclusive).
#[derive(Clone, Copy, PartialEq)]
enum Format {
    Text,
    Csv,
    Json,
}

impl Format {
    fn from_opts(opts: &Opts) -> Result<Self, String> {
        match (opts.flag("--csv"), opts.flag("--json")) {
            (true, true) => Err("--csv and --json are mutually exclusive".into()),
            (true, false) => Ok(Format::Csv),
            (false, true) => Ok(Format::Json),
            (false, false) => Ok(Format::Text),
        }
    }

    fn render(&self, artifacts: &[Artifact]) -> String {
        match self {
            Format::Text => render_all_text(artifacts),
            Format::Csv => render_all_csv(artifacts),
            Format::Json => {
                let mut out = render_all_json(artifacts);
                out.push('\n');
                out
            }
        }
    }
}

fn accel_config(opts: &Opts) -> Result<AccelConfig, String> {
    let mut cfg = match opts.value("--config") {
        None => AccelConfig::default(),
        Some(path) => {
            bp_im2col::accel::config_file::load(path).map_err(|e| format!("{e:#}"))?
        }
    };
    if let Some(v) = opts.value("--bandwidth") {
        let bw: f64 = v.parse().map_err(|_| format!("bad --bandwidth {v:?}"))?;
        cfg.dram.elems_per_cycle = bw;
    }
    if let Some(v) = opts.value("--lowering") {
        cfg.lowering = bp_im2col::sparse::SparseLowering::parse(v)?;
    }
    if let Some(v) = opts.value("--lowering-strategy") {
        cfg.strategy = bp_im2col::accel::strategy::LoweringSelect::parse(v)?;
    }
    if let Some(v) = opts.value("--objective") {
        cfg.objective = bp_im2col::accel::strategy::AutoObjective::parse(v)?;
    }
    if let Some(v) = opts.value("--density") {
        let f: f64 = v.parse().map_err(|_| format!("bad --density {v:?}"))?;
        if !(f > 0.0 && f <= 1.0) {
            return Err(format!("--density must be in (0, 1], got {v}"));
        }
        // Same fixed-point convention as the layer knob and the DSE
        // axis: thousandths, floored to at least 1.
        cfg.density_millis = ((f * 1000.0).round() as usize).max(1);
    }
    Ok(cfg)
}

/// Parse `--devices N` (None when absent).
fn devices(opts: &Opts) -> Result<Option<usize>, String> {
    match opts.value("--devices") {
        None => Ok(None),
        Some(v) => {
            let n: usize = v.parse().map_err(|_| format!("bad --devices {v:?}"))?;
            if n == 0 {
                return Err("--devices must be >= 1".into());
            }
            Ok(Some(n))
        }
    }
}

/// Build one figure request from the command's options.
fn figure_request(figure: Figure, opts: &Opts) -> Result<FigureRequest, String> {
    let mut req = FigureRequest::new(figure).extended(opts.flag("--extended"));
    match opts.value("--pass") {
        None => {}
        Some("loss") => req = req.pass(Pass::Loss),
        Some("grad") => req = req.pass(Pass::Grad),
        Some(o) => return Err(format!("bad --pass {o:?} (loss|grad)")),
    }
    if let Some(n) = devices(opts)? {
        req = req.devices(n);
    }
    Ok(req)
}

/// Map a parsed command line onto the facade's typed requests — the
/// entire command dispatch. `all` expands to the full report sequence.
fn build_requests(cmd: &str, opts: &Opts) -> Result<Vec<SimRequest>, String> {
    let extended = opts.flag("--extended");
    Ok(match cmd {
        "table2" => vec![SimRequest::Table2],
        "table3" => vec![SimRequest::Table3],
        "table4" => vec![SimRequest::Table4],
        "fig6" => vec![figure_request(Figure::Runtime, opts)?.into()],
        "fig7" => vec![figure_request(Figure::OffChipTraffic, opts)?.into()],
        "fig8" => vec![figure_request(Figure::BufferReads, opts)?.into()],
        "sparsity" => vec![SimRequest::Sparsity { extended }],
        "storage" => vec![SimRequest::Storage { extended }],
        "sparse" => vec![SimRequest::Sparse { extended }],
        "sim" => {
            let spec = opts.value("--layer").ok_or(
                "sim requires --layer H/C/N/K/S/P[/G[/D]] \
                 (e.g. --layer 56/128/128/3/2/1/g32; see `repro help`)",
            )?;
            vec![SimRequest::layer(ConvParams::parse_spec(spec)?)]
        }
        "traincost" => vec![SimRequest::TrainCost { devices: devices(opts)? }],
        "autotune" => vec![SimRequest::Autotune { extended, devices: devices(opts)? }],
        "trace" => vec![SimRequest::Trace { extended, devices: devices(opts)? }],
        "profile" => vec![SimRequest::Profile],
        "fleet" => {
            let n = devices(opts)?.unwrap_or(4);
            vec![FleetRequest::new(n).extended(extended).into()]
        }
        "dse" => {
            let mut req = DseRequest::new().extended(extended);
            if let Some(v) = opts.value("--budget") {
                req.budget = v.parse().map_err(|_| format!("bad --budget {v:?}"))?;
            }
            if let Some(v) = opts.value("--seed") {
                req.seed = v.parse().map_err(|_| format!("bad --seed {v:?}"))?;
            }
            if let Some(spec) = opts.value("--layer") {
                if extended {
                    return Err("--extended and --layer are mutually exclusive for dse".into());
                }
                req.workloads = DseWorkloads::Layer(ConvParams::parse_spec(spec)?);
            }
            if let Some(n) = devices(opts)? {
                req.devices = Some(n);
            }
            let mut axis_keys: Vec<&str> = Vec::new();
            for axis in opts.values_all("--axis") {
                let (key, range) = axis.split_once('=').ok_or_else(|| {
                    format!("--axis needs KEY=RANGE (e.g. array_dim=8:16:8), got {axis:?}")
                })?;
                // Last-wins would silently drop the earlier override —
                // the same footgun the config-file parser rejects.
                if axis_keys.contains(&key) {
                    return Err(format!("duplicate --axis key {key:?}"));
                }
                axis_keys.push(key);
                req.space.set_axis(key, range)?;
            }
            let req: SimRequest = req.into();
            // Surface budget/seed/space errors here, with the CLI's
            // clean error prefix, instead of panicking inside the model.
            req.validate()?;
            vec![req]
        }
        "all" => {
            let mut reqs = vec![SimRequest::Table2, SimRequest::Table3, SimRequest::Table4];
            for figure in Figure::ALL {
                // One trailing fleet summary for the whole report, not
                // one identical sibling per figure.
                let mut fig = figure_request(figure, opts)?;
                fig.devices = None;
                reqs.push(fig.into());
            }
            reqs.push(SimRequest::Storage { extended });
            if let Some(n) = devices(opts)? {
                reqs.push(FleetRequest::new(n).extended(extended).into());
            }
            reqs
        }
        other => return Err(format!("unknown command {other:?}\n\n{USAGE}")),
    })
}

/// `serve`: bind the HTTP frontend and run it until the shutdown
/// sentinel arrives. Prints the bound address first (on one line, so
/// scripts binding port 0 can scrape the ephemeral port).
fn cmd_serve(opts: &Opts) -> Result<(), String> {
    use bp_im2col::server::{Frontend, ServeOptions, Server};
    use std::io::Write as _;
    let cfg = accel_config(opts)?;
    let addr = opts.value("--addr").unwrap_or(bp_im2col::server::DEFAULT_ADDR);
    let threads = match opts.value("--threads") {
        None => bp_im2col::server::default_threads(),
        Some(v) => {
            let n: usize = v.parse().map_err(|_| format!("bad --threads {v:?}"))?;
            if n == 0 {
                return Err("--threads must be >= 1".into());
            }
            n
        }
    };
    let mut serve_opts = ServeOptions::for_threads(threads);
    if let Some(v) = opts.value("--frontend") {
        serve_opts.frontend = match v {
            "event" => Frontend::EventLoop,
            "pool" => Frontend::BlockingPool,
            other => return Err(format!("bad --frontend {other:?} (expected event or pool)")),
        };
    }
    if let Some(v) = opts.value("--max-conns") {
        let n: usize = v.parse().map_err(|_| format!("bad --max-conns {v:?}"))?;
        if n == 0 {
            return Err("--max-conns must be >= 1".into());
        }
        serve_opts.max_conns = n;
    }
    if let Some(v) = opts.value("--shed-queue") {
        let n: usize = v.parse().map_err(|_| format!("bad --shed-queue {v:?}"))?;
        if n == 0 {
            return Err("--shed-queue must be >= 1".into());
        }
        serve_opts.shed_queue = n;
    }
    let server = Server::bind_with(cfg, addr, serve_opts)
        .map_err(|e| format!("cannot bind {addr}: {e}"))?;
    let core = match serve_opts.frontend {
        Frontend::EventLoop => "event loop",
        Frontend::BlockingPool => "blocking pool",
    };
    println!(
        "repro serve: listening on http://{} ({threads} worker threads, {core} frontend)",
        server.local_addr()
    );
    let _ = std::io::stdout().flush();
    server.serve().map_err(|e| format!("serve failed: {e}"))?;
    println!("repro serve: shut down cleanly");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_opts: &Opts) -> Result<(), String> {
    Err("the `train` command needs the PJRT runtime — uncomment the xla/anyhow \
         [dependencies] in rust/Cargo.toml, then rebuild with `--features pjrt`"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_train(opts: &Opts) -> Result<(), String> {
    let steps =
        opts.value("--steps").map(|v| v.parse().map_err(|_| "bad --steps")).transpose()?.unwrap_or(300);
    let seed =
        opts.value("--seed").map(|v| v.parse().map_err(|_| "bad --seed")).transpose()?.unwrap_or(0);
    let rt = Runtime::cpu().map_err(|e| format!("{e:#}"))?;
    if !rt.has_artifact("train_step") {
        return Err("artifacts/train_step.hlo.txt missing — run `make artifacts` first".into());
    }
    println!("platform: {}", rt.platform());
    let trainer =
        Trainer::new(&rt, TrainConfig { steps, seed, log_every: 25 }).map_err(|e| format!("{e:#}"))?;
    let stats = trainer.train().map_err(|e| format!("{e:#}"))?;
    println!(
        "\ntrained {steps} steps in {:.1}s: loss {:.4} -> {:.4}",
        stats.wall_seconds, stats.initial_loss, stats.final_loss
    );
    println!(
        "simulated accelerator cycles per step: traditional {:.0}, BP-im2col {:.0} ({:.2}x)",
        stats.sim_cycles_traditional,
        stats.sim_cycles_bp,
        stats.sim_cycles_traditional / stats.sim_cycles_bp
    );
    Ok(())
}

/// `lint`: run the static analyzer over the given paths (or the
/// default roots), render the findings artifact, and report the exit
/// status — nonzero when any unsuppressed finding remains, so CI can
/// gate on it directly.
fn cmd_lint(opts: &Opts) -> Result<ExitCode, String> {
    use std::path::PathBuf;
    let paths: Vec<PathBuf> = if opts.positionals.is_empty() {
        bp_im2col::lint::default_roots()
    } else {
        opts.positionals.iter().map(PathBuf::from).collect()
    };
    if paths.is_empty() {
        return Err("lint: no scan roots found (run from the repo root or rust/)".into());
    }
    for p in &paths {
        if !p.exists() {
            return Err(format!("lint: no such path {}", p.display()));
        }
    }
    let report = bp_im2col::lint::lint_paths(&paths);
    let art = bp_im2col::lint::artifact(&report);
    let rendered = if opts.flag("--json") {
        Format::Json.render(std::slice::from_ref(&art))
    } else {
        Format::Text.render(std::slice::from_ref(&art))
    };
    print!("{rendered}");
    if report.is_clean() {
        Ok(ExitCode::SUCCESS)
    } else {
        eprintln!(
            "lint: {} unsuppressed finding(s) across {} files",
            report.findings.len(),
            report.files
        );
        Ok(ExitCode::FAILURE)
    }
}

fn run() -> Result<ExitCode, String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(ExitCode::SUCCESS);
    };
    if matches!(cmd.as_str(), "help" | "--help" | "-h") {
        print!("{USAGE}");
        return Ok(ExitCode::SUCCESS);
    }
    let Some(spec) = COMMANDS.iter().find(|c| c.name == cmd) else {
        return Err(format!("unknown command {cmd:?}\n\n{USAGE}"));
    };
    let opts = Opts::parse(&argv[1..], spec)?;
    let format = Format::from_opts(&opts)?;
    if cmd == "lint" {
        return cmd_lint(&opts);
    }
    if cmd == "train" {
        return cmd_train(&opts).map(|()| ExitCode::SUCCESS);
    }
    if cmd == "serve" {
        return cmd_serve(&opts).map(|()| ExitCode::SUCCESS);
    }
    let cfg = accel_config(&opts)?;
    let requests = build_requests(&cmd, &opts)?;
    let service = Service::new(cfg);
    let artifacts: Vec<Artifact> = if requests.len() > 1 {
        // `all`: serve the whole report sequence concurrently through
        // the shared plan cache, print in request order. Per-request
        // failures surface as the command's error (CLI requests are
        // pre-validated, so this is a can't-happen backstop).
        let mut artifacts = Vec::new();
        for result in service.run_batch(&requests) {
            artifacts.extend(result.map_err(|e| e.to_string())?);
        }
        artifacts
    } else {
        service.run(&requests[0])
    };
    if cmd == "trace" {
        if let Some(path) = opts.value("--out") {
            // The Chrome export shares the deterministic virtual-time
            // replay with the artifact above — same bytes every run.
            let json = service.trace_chrome_json(opts.flag("--extended"));
            std::fs::write(path, json).map_err(|e| format!("cannot write {path:?}: {e}"))?;
            eprintln!("wrote Chrome trace-event JSON to {path}");
        }
    }
    print!("{}", format.render(&artifacts));
    Ok(ExitCode::SUCCESS)
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parsed(cmd: &str, args: &[&str]) -> Opts {
        let spec = COMMANDS.iter().find(|c| c.name == cmd).unwrap();
        let args: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        Opts::parse(&args, spec).unwrap()
    }

    #[test]
    fn all_with_devices_appends_exactly_one_fleet_request() {
        let opts = parsed("all", &["--devices", "4"]);
        let reqs = build_requests("all", &opts).unwrap();
        let fleets = reqs.iter().filter(|r| matches!(r, SimRequest::Fleet(_))).count();
        assert_eq!(fleets, 1, "one trailing fleet, not one per figure");
        for r in &reqs {
            if let SimRequest::Figure(f) = r {
                assert_eq!(f.devices, None, "figures must not carry fleet siblings in `all`");
            }
        }
        assert_eq!(reqs.len(), 8); // 3 tables + 3 figures + storage + fleet
    }

    #[test]
    fn all_without_devices_has_no_fleet_request() {
        let reqs = build_requests("all", &parsed("all", &[])).unwrap();
        assert!(!reqs.iter().any(|r| matches!(r, SimRequest::Fleet(_))));
        assert_eq!(reqs.len(), 7);
    }

    #[test]
    fn dse_accepts_repeated_axis_overrides() {
        let opts = parsed(
            "dse",
            &[
                "--budget",
                "32",
                "--seed",
                "7",
                "--axis",
                "array_dim=4:16:4",
                "--axis",
                "sparse_skip=0:1:1",
            ],
        );
        let reqs = build_requests("dse", &opts).unwrap();
        let [SimRequest::Dse(d)] = reqs.as_slice() else { panic!("{reqs:?}") };
        assert_eq!((d.budget, d.seed), (32, 7));
        assert_eq!(d.space.axis_string(0), "4:16:4");
        assert_eq!(d.space.axis_string(7), "0:1:1");
    }

    #[test]
    fn sim_takes_sparse_platform_knobs_and_sparse_builds_its_request() {
        let opts = parsed(
            "sim",
            &["--layer", "224/3/64/3/2/0/w250/a600", "--lowering", "spots", "--density", "0.5"],
        );
        let cfg = accel_config(&opts).unwrap();
        assert_eq!(cfg.lowering, bp_im2col::sparse::SparseLowering::Spots);
        assert_eq!(cfg.density_millis, 500);
        let reqs = build_requests("sim", &opts).unwrap();
        let [SimRequest::Layer(p)] = reqs.as_slice() else { panic!("{reqs:?}") };
        assert_eq!((p.density.weight_millis, p.density.act_millis), (250, 600));
        // The long alias parses too; bad spellings and domains are errors.
        let opts = parsed("sim", &["--layer", "224/3/64/3/2/0", "--lowering", "column-combine"]);
        assert_eq!(
            accel_config(&opts).unwrap().lowering,
            bp_im2col::sparse::SparseLowering::ColumnCombine
        );
        let opts = parsed("sim", &["--layer", "224/3/64/3/2/0", "--lowering", "csr"]);
        assert!(accel_config(&opts).is_err());
        let opts = parsed("sim", &["--layer", "224/3/64/3/2/0", "--density", "0"]);
        assert!(accel_config(&opts).is_err());
        let opts = parsed("sim", &["--layer", "224/3/64/3/2/0", "--density", "1.5"]);
        assert!(accel_config(&opts).is_err());
        // The sparse command is a plain extended-or-not query.
        let reqs = build_requests("sparse", &parsed("sparse", &["--extended"])).unwrap();
        assert_eq!(reqs, vec![SimRequest::Sparse { extended: true }]);
        // And the sparse platform knobs stay sim-only at parse time.
        let table2 = COMMANDS.iter().find(|c| c.name == "table2").unwrap();
        let bad: Vec<String> = ["--lowering".into(), "spots".into()].to_vec();
        assert!(Opts::parse(&bad, table2).is_err());
    }

    #[test]
    fn autotune_and_strategy_options_parse() {
        use bp_im2col::accel::strategy::{AutoObjective, LoweringSelect, LoweringStrategy};
        let opts = parsed("autotune", &["--extended", "--devices", "4", "--objective", "traffic"]);
        let reqs = build_requests("autotune", &opts).unwrap();
        assert_eq!(reqs, vec![SimRequest::Autotune { extended: true, devices: Some(4) }]);
        assert_eq!(accel_config(&opts).unwrap().objective, AutoObjective::Traffic);
        // --lowering-strategy is universal: it reconfigures any query
        // command's platform, with auto as the per-layer selector.
        let opts = parsed("fig6", &["--lowering-strategy", "eco-os"]);
        assert_eq!(
            accel_config(&opts).unwrap().strategy,
            LoweringSelect::Fixed(LoweringStrategy::EcoOutputStationary)
        );
        let opts = parsed("table2", &["--lowering-strategy", "auto"]);
        assert_eq!(accel_config(&opts).unwrap().strategy, LoweringSelect::Auto);
        let opts = parsed("table2", &["--lowering-strategy", "nope"]);
        assert!(accel_config(&opts).is_err());
        // --objective stays autotune-only at parse time.
        let table2 = COMMANDS.iter().find(|c| c.name == "table2").unwrap();
        let bad: Vec<String> = ["--objective".into(), "reads".into()].to_vec();
        assert!(Opts::parse(&bad, table2).is_err());
    }

    #[test]
    fn trace_and_profile_options_parse() {
        let opts = parsed("trace", &["--extended", "--devices", "8", "--out", "/tmp/t.json"]);
        let reqs = build_requests("trace", &opts).unwrap();
        assert_eq!(reqs, vec![SimRequest::Trace { extended: true, devices: Some(8) }]);
        assert_eq!(opts.value("--out"), Some("/tmp/t.json"));
        let reqs = build_requests("trace", &parsed("trace", &[])).unwrap();
        assert_eq!(reqs, vec![SimRequest::Trace { extended: false, devices: None }]);
        let reqs = build_requests("profile", &parsed("profile", &[])).unwrap();
        assert_eq!(reqs, vec![SimRequest::Profile]);
        // --out is trace-only; profile takes no extras beyond the
        // universal set — both stay parse-time errors elsewhere.
        let autotune = COMMANDS.iter().find(|c| c.name == "autotune").unwrap();
        let bad = ["--out".to_string(), "x.json".to_string()];
        assert!(Opts::parse(&bad, autotune).is_err());
        let profile = COMMANDS.iter().find(|c| c.name == "profile").unwrap();
        assert!(Opts::parse(&bad, profile).is_err());
        let dev = ["--devices".to_string(), "2".to_string()];
        assert!(Opts::parse(&dev, profile).is_err());
    }

    #[test]
    fn dse_rejects_malformed_options() {
        let spec = COMMANDS.iter().find(|c| c.name == "dse").unwrap();
        // Only --axis may repeat.
        let dup: Vec<String> =
            ["--budget", "8", "--budget", "9"].iter().map(|s| s.to_string()).collect();
        assert!(Opts::parse(&dup, spec).is_err(), "duplicate --budget");
        let axes: Vec<String> = ["--axis", "array_dim=8", "--axis", "burst_len=32"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(Opts::parse(&axes, spec).is_ok(), "repeated --axis");
        // KEY=RANGE shape, workload conflicts, range errors.
        let opts = parsed("dse", &["--axis", "array_dim"]);
        assert!(build_requests("dse", &opts).unwrap_err().contains("KEY=RANGE"));
        let opts = parsed("dse", &["--extended", "--layer", "56/128/128/3/2/1"]);
        assert!(build_requests("dse", &opts).unwrap_err().contains("mutually exclusive"));
        let opts = parsed("dse", &["--budget", "0"]);
        assert!(build_requests("dse", &opts).unwrap_err().contains("budget"));
        let opts = parsed("dse", &["--axis", "array_dim=8:32:8"]);
        assert!(build_requests("dse", &opts).unwrap_err().contains("array_dim"));
        // Repeating the same axis KEY is an error (distinct keys repeat
        // fine) — last-wins would silently drop the first override.
        let opts = parsed("dse", &["--axis", "array_dim=8", "--axis", "array_dim=16"]);
        assert!(build_requests("dse", &opts).unwrap_err().contains("duplicate --axis"));
    }

    #[test]
    fn serve_spec_rejects_render_options_but_takes_config() {
        let spec = COMMANDS.iter().find(|c| c.name == "serve").unwrap();
        for opt in ["--csv", "--json"] {
            assert!(Opts::parse(&[opt.to_string()], spec).is_err(), "{opt}");
        }
        let ok = [
            "--addr".to_string(),
            "127.0.0.1:0".to_string(),
            "--threads".to_string(),
            "2".to_string(),
            "--config".to_string(),
            "configs/edge.cfg".to_string(),
            "--frontend".to_string(),
            "event".to_string(),
            "--max-conns".to_string(),
            "64".to_string(),
            "--shed-queue".to_string(),
            "4".to_string(),
        ];
        assert!(Opts::parse(&ok, spec).is_ok());
        // The event-loop tuning flags are serve-only: every other
        // command must reject them at parse time.
        let table2 = COMMANDS.iter().find(|c| c.name == "table2").unwrap();
        let bad = ["--max-conns".to_string(), "64".to_string()];
        assert!(Opts::parse(&bad, table2).is_err());
    }

    #[test]
    fn lint_takes_positionals_other_commands_reject_them() {
        let spec = COMMANDS.iter().find(|c| c.name == "lint").unwrap();
        let args: Vec<String> = ["src", "--json", "tests"].iter().map(|s| s.to_string()).collect();
        let opts = Opts::parse(&args, spec).unwrap();
        assert_eq!(opts.positionals, vec!["src", "tests"]);
        assert!(opts.flag("--json"));
        let table2 = COMMANDS.iter().find(|c| c.name == "table2").unwrap();
        assert!(Opts::parse(&args, table2).is_err(), "positionals stay errors elsewhere");
        assert!(Opts::parse(&["--csv".to_string()], spec).is_err(), "lint has no CSV mode");
    }

    #[test]
    fn train_spec_rejects_universal_options() {
        let spec = COMMANDS.iter().find(|c| c.name == "train").unwrap();
        for opt in UNIVERSAL_OPTS {
            assert!(Opts::parse(&[opt.to_string()], spec).is_err(), "{opt}");
        }
        assert!(Opts::parse(&["--steps".into(), "5".into()], spec).is_ok());
    }
}
