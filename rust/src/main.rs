//! `repro` — CLI of the BP-im2col reproduction.
//!
//! Subcommands regenerate each experiment of the paper (see DESIGN.md §4)
//! on the simulated TPU-like accelerator, run end-to-end training through
//! the AOT HLO artifacts, or simulate individual layers.
//!
//! The offline image has no clap; argument parsing is hand-rolled.

use std::process::ExitCode;

use bp_im2col::accel::AccelConfig;
use bp_im2col::accel::{metrics::speedup, simulate_pass};
use bp_im2col::conv::ConvParams;
#[cfg(feature = "pjrt")]
use bp_im2col::coordinator::{TrainConfig, Trainer};
use bp_im2col::im2col::pipeline::{Mode, Pass};
use bp_im2col::report;
#[cfg(feature = "pjrt")]
use bp_im2col::runtime::Runtime;
use bp_im2col::workloads;

const USAGE: &str = "\
repro — BP-Im2col reproduction (Yang et al., 2022)

USAGE: repro <COMMAND> [OPTIONS]

COMMANDS:
  table2                Runtime of Table II's five layers, both passes
  table3                Prologue latency of the address-gen modules
  table4                Area of the address-gen modules (ASAP7 model)
  fig6                  Backprop runtime per network (loss+grad)
  fig7                  Off-chip bandwidth per network
  fig8                  On-chip buffer bandwidth + sparsity per network
  sparsity              Lowered-matrix sparsity of every workload layer
  storage               Additional-storage overhead per network
  sim --layer <SPEC>    Simulate one layer in both modes (spec below)
  traincost             Full training-step cost (fwd+loss+grad) per network
  fleet                 Backward-pass sharding across N simulated
                        accelerators (makespan, efficiency, plan cache)
  train [--steps N]     End-to-end training via the AOT HLO artifacts.
                        NOTE: requires the `pjrt` build feature — uncomment
                        the xla/anyhow [dependencies] in rust/Cargo.toml and
                        build with `--features pjrt`
  all                   Every table and figure, in order

LAYER SPEC (sim --layer):
  H/C/N/K/S/P[/G[/D]]   H input size, C in-channels, N out-channels,
                        K kernel, S stride, P padding — the paper's
                        Hi(Wi)/C/N/Kh(Kw)/S/Ph(Pw) notation. Optional:
                        G channel groups, D kernel dilation. S and D also
                        accept asymmetric `HxW` forms (e.g. S=2x1), and
                        G/D may be tagged in any order as `gG` / `dD`.
  examples:
    repro sim --layer 224/3/64/3/2/0          (Table II row 1)
    repro sim --layer 56/128/128/3/2/1/g32    (ResNeXt-style, 32 groups)
    repro sim --layer 28/256/256/3/1/2/d2     (DeepLab-style, dilation 2)
    repro sim --layer 56/64/64/3/2x1/1        (asymmetric stride)

OPTIONS:
  --config <file.cfg>         Platform preset (see configs/)
  --bandwidth <elems/cycle>   Off-chip bandwidth override (default 16)
  --csv                       Emit CSV instead of rendered tables (figs)
  --pass loss|grad            Restrict fig6/7/8 to one pass
  --extended                  Include the dilated/grouped workload networks
  --devices N                 Shard fig6/7/8/traincost/fleet backward
                              passes across N simulated accelerators
                              (fleet default 4; totals are bit-identical
                              for any N, the fleet summary shows scaling;
                              suppressed under --csv on figure commands —
                              use `fleet --csv` for machine-readable rows)
  --steps N                   Training steps (train; default 300)
  --seed N                    Training seed (train; default 0)
";

/// Minimal option scanner: `--key value` pairs + flags.
struct Opts {
    args: Vec<String>,
}

impl Opts {
    fn value(&self, key: &str) -> Option<&str> {
        self.args
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.args.get(i + 1))
            .map(|s| s.as_str())
    }

    fn flag(&self, key: &str) -> bool {
        self.args.iter().any(|a| a == key)
    }
}

/// Parse one `A` or `AxB` pair (strides, dilation).
fn parse_pair(s: &str) -> Result<(usize, usize), String> {
    let bad = || format!("bad layer component {s:?}");
    match s.split_once('x') {
        None => {
            let v: usize = s.parse().map_err(|_| bad())?;
            Ok((v, v))
        }
        Some((a, b)) => {
            Ok((a.parse().map_err(|_| bad())?, b.parse().map_err(|_| bad())?))
        }
    }
}

/// Parse a layer spec. Accepts both the input form
/// `H/C/N/K/S/P[/G[/D]]` (bare numerics, groups then dilation) and the
/// exact strings [`ConvParams::id`] prints (`S` may be `ShxSw`;
/// suffixes `dD`/`dDhxDw` and `gG` in any order) — so every layer id in
/// the tool's own output round-trips through `sim --layer`.
fn parse_layer(spec: &str) -> Result<ConvParams, String> {
    let parts: Vec<&str> = spec.split('/').collect();
    if !(6..=8).contains(&parts.len()) {
        return Err(format!("layer spec must be H/C/N/K/S/P[/G[/D]], got {spec:?}"));
    }
    let num = |s: &str| -> Result<usize, String> {
        s.parse().map_err(|_| format!("bad layer component {s:?}"))
    };
    let (hi, c, n) = (num(parts[0])?, num(parts[1])?, num(parts[2])?);
    let (k, ph) = (num(parts[3])?, num(parts[5])?);
    let (sh, sw) = parse_pair(parts[4])?;
    let mut p = ConvParams::square(hi, c, n, k, 1, ph).with_stride(sh, sw);
    let mut positional = 0usize;
    for extra in &parts[6..] {
        if let Some(rest) = extra.strip_prefix('d') {
            let (dh, dw) = parse_pair(rest)?;
            p = p.with_dilation(dh, dw);
        } else if let Some(rest) = extra.strip_prefix('g') {
            p = p.with_groups(num(rest)?);
        } else if positional == 0 {
            p = p.with_groups(num(extra)?);
            positional += 1;
        } else {
            let d = num(extra)?;
            p = p.with_dilation(d, d);
        }
    }
    p.validate()?;
    Ok(p)
}

fn accel_config(opts: &Opts) -> Result<AccelConfig, String> {
    let mut cfg = match opts.value("--config") {
        None => AccelConfig::default(),
        Some(path) => {
            bp_im2col::accel::config_file::load(path).map_err(|e| format!("{e:#}"))?
        }
    };
    if let Some(v) = opts.value("--bandwidth") {
        let bw: f64 = v.parse().map_err(|_| format!("bad --bandwidth {v:?}"))?;
        cfg.dram.elems_per_cycle = bw;
    }
    Ok(cfg)
}

/// Parse `--devices N` (None when absent).
fn devices(opts: &Opts) -> Result<Option<usize>, String> {
    match opts.value("--devices") {
        None => Ok(None),
        Some(v) => {
            let n: usize = v.parse().map_err(|_| format!("bad --devices {v:?}"))?;
            if n == 0 {
                return Err("--devices must be >= 1".into());
            }
            Ok(Some(n))
        }
    }
}

/// Print the fleet-scaling summary for the given networks.
fn print_fleet_summary_for(
    nets: &[workloads::Network],
    cfg: &AccelConfig,
    opts: &Opts,
    n_devices: usize,
) -> Result<(), String> {
    let (bars, planning) = report::fleet_summary(nets, cfg, Mode::BpIm2col, n_devices);
    if opts.flag("--csv") {
        print!("{}", report::fleet_to_csv(&bars));
    } else {
        println!("{}", report::render_fleet(n_devices, &bars, &planning));
    }
    Ok(())
}

/// Print the fleet-scaling summary for the `--extended`-selected set.
fn print_fleet_summary(cfg: &AccelConfig, opts: &Opts, n_devices: usize) -> Result<(), String> {
    print_fleet_summary_for(&networks(opts), cfg, opts, n_devices)
}

fn passes(opts: &Opts) -> Result<Vec<Pass>, String> {
    match opts.value("--pass") {
        None => Ok(vec![Pass::Loss, Pass::Grad]),
        Some("loss") => Ok(vec![Pass::Loss]),
        Some("grad") => Ok(vec![Pass::Grad]),
        Some(o) => Err(format!("bad --pass {o:?} (loss|grad)")),
    }
}

/// Workload set selected by `--extended` (the paper's six networks plus
/// the dilated/grouped ones).
fn networks(opts: &Opts) -> Vec<workloads::Network> {
    if opts.flag("--extended") {
        workloads::extended_networks()
    } else {
        workloads::all_networks()
    }
}

fn cmd_fig(which: u8, cfg: &AccelConfig, opts: &Opts) -> Result<(), String> {
    let nets = networks(opts);
    for pass in passes(opts)? {
        let panel = if pass == Pass::Loss { "a" } else { "b" };
        let (bars, title, with_sparsity) = match which {
            6 => (
                report::fig6_for(&nets, cfg, pass),
                format!("Fig 6{panel}: {}-calculation runtime reduction", pass.name()),
                false,
            ),
            7 => (
                report::fig7_for(&nets, cfg, pass),
                format!("Fig 7{panel}: off-chip traffic reduction ({} calc)", pass.name()),
                false,
            ),
            8 => (
                report::fig8_for(&nets, cfg, pass),
                format!("Fig 8{panel}: on-chip buffer bandwidth reduction ({} calc)", pass.name()),
                true,
            ),
            _ => unreachable!(),
        };
        if opts.flag("--csv") {
            print!("{}", report::bars_to_csv(&bars));
        } else {
            println!("{}", report::render_bars(&title, &bars, with_sparsity));
        }
    }
    // With --devices N the same backward passes shard across a fleet;
    // totals are bit-identical, the summary shows the scaling. Under
    // --csv the summary is suppressed so stdout stays one parseable CSV
    // document — use `repro fleet --csv` for machine-readable scaling.
    if let Some(n) = devices(opts)? {
        if !opts.flag("--csv") {
            print_fleet_summary(cfg, opts, n)?;
        }
    }
    Ok(())
}

fn cmd_sim(cfg: &AccelConfig, opts: &Opts) -> Result<(), String> {
    let spec = opts.value("--layer").ok_or(
        "sim requires --layer H/C/N/K/S/P[/G[/D]] \
         (e.g. --layer 56/128/128/3/2/1/g32; see `repro help`)",
    )?;
    let p = parse_layer(spec)?;
    println!("layer {} (batch {}):", p.id(), p.b);
    for pass in Pass::ALL {
        let trad = simulate_pass(pass, Mode::Traditional, &p, cfg);
        let bp = simulate_pass(pass, Mode::BpIm2col, &p, cfg);
        println!(
            "  {:<4}  BP {:>12.0} cyc | trad {:>12.0} comp + {:>12.0} reorg | speedup {:>5.2}x | sparsity {:>5.2}%",
            pass.name(),
            bp.total_cycles(),
            trad.total_cycles() - trad.reorg_cycles,
            trad.reorg_cycles,
            speedup(&trad, &bp),
            bp.sparsity * 100.0,
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_opts: &Opts) -> Result<(), String> {
    Err("the `train` command needs the PJRT runtime — uncomment the xla/anyhow \
         [dependencies] in rust/Cargo.toml, then rebuild with `--features pjrt`"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_train(opts: &Opts) -> Result<(), String> {
    let steps =
        opts.value("--steps").map(|v| v.parse().map_err(|_| "bad --steps")).transpose()?.unwrap_or(300);
    let seed =
        opts.value("--seed").map(|v| v.parse().map_err(|_| "bad --seed")).transpose()?.unwrap_or(0);
    let rt = Runtime::cpu().map_err(|e| format!("{e:#}"))?;
    if !rt.has_artifact("train_step") {
        return Err("artifacts/train_step.hlo.txt missing — run `make artifacts` first".into());
    }
    println!("platform: {}", rt.platform());
    let trainer =
        Trainer::new(&rt, TrainConfig { steps, seed, log_every: 25 }).map_err(|e| format!("{e:#}"))?;
    let stats = trainer.train().map_err(|e| format!("{e:#}"))?;
    println!(
        "\ntrained {steps} steps in {:.1}s: loss {:.4} -> {:.4}",
        stats.wall_seconds, stats.initial_loss, stats.final_loss
    );
    println!(
        "simulated accelerator cycles per step: traditional {:.0}, BP-im2col {:.0} ({:.2}x)",
        stats.sim_cycles_traditional,
        stats.sim_cycles_bp,
        stats.sim_cycles_traditional / stats.sim_cycles_bp
    );
    Ok(())
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    let opts = Opts { args: argv[1..].to_vec() };
    let cfg = accel_config(&opts)?;
    match cmd.as_str() {
        "table2" => print!("{}", report::render_table2(&report::table2(&cfg))),
        "table3" => print!("{}", report::render_table3()),
        "table4" => print!("{}", report::render_table4()),
        "fig6" => cmd_fig(6, &cfg, &opts)?,
        "fig7" => cmd_fig(7, &cfg, &opts)?,
        "fig8" => cmd_fig(8, &cfg, &opts)?,
        "sparsity" => {
            let nets = networks(&opts);
            let layers: Vec<ConvParams> =
                nets.iter().flat_map(|n| n.layers.iter().map(|l| l.params)).collect();
            print!("{}", report::render_sparsity(&layers));
            let ((lmin, lmax), (gmin, gmax)) = report::sparsity_ranges();
            println!(
                "\nloss matrix B sparsity range: {:.2}%..{:.2}% (paper: 75..93.91%)",
                lmin * 100.0,
                lmax * 100.0
            );
            println!(
                "grad matrix A sparsity range: {:.2}%..{:.2}% (paper: 74.8..93.6%)",
                gmin * 100.0,
                gmax * 100.0
            );
        }
        "storage" => {
            let bars = report::storage_for(&networks(&opts), &cfg);
            if opts.flag("--csv") {
                print!("{}", report::bars_to_csv(&bars));
            } else {
                println!(
                    "{}",
                    report::render_bars("Additional storage overhead reduction", &bars, false)
                );
            }
        }
        "sim" => cmd_sim(&cfg, &opts)?,
        "traincost" => {
            use bp_im2col::accel::inference::training_step_cost;
            let mut rows = Vec::new();
            for net in workloads::all_networks() {
                let mut sum = [0.0f64; 2]; // per mode
                let mut fwd = 0.0f64;
                for l in &net.layers {
                    for (mi, mode) in Mode::ALL.iter().enumerate() {
                        let c = training_step_cost(&l.params, *mode, &cfg);
                        sum[mi] += (c.loss + c.grad) * l.count as f64;
                        if mi == 0 {
                            fwd += c.fwd * l.count as f64;
                        }
                    }
                }
                rows.push(vec![
                    net.name.to_string(),
                    format!("{:.0}", fwd + sum[0]),
                    format!("{:.0}", fwd + sum[1]),
                    format!("{:.2}x", (fwd + sum[0]) / (fwd + sum[1])),
                    format!("{:.1}%", sum[1] / (fwd + sum[1]) * 100.0),
                ]);
            }
            print!(
                "{}",
                report::fmt_table(
                    &["network", "step cycles (trad)", "step cycles (BP)", "speedup", "bwd share (BP)"],
                    &rows
                )
            );
            // Same guard as the figure commands (keep stdout one format)
            // and the same network set as the table above.
            if let Some(n) = devices(&opts)? {
                if !opts.flag("--csv") {
                    println!();
                    print_fleet_summary_for(&workloads::all_networks(), &cfg, &opts, n)?;
                }
            }
        }
        "fleet" => {
            let n = devices(&opts)?.unwrap_or(4);
            print_fleet_summary(&cfg, &opts, n)?;
        }
        "train" => cmd_train(&opts)?,
        "all" => {
            println!("== Table II ==\n{}", report::render_table2(&report::table2(&cfg)));
            println!("== Table III ==\n{}", report::render_table3());
            println!("== Table IV ==\n{}", report::render_table4());
            for w in [6u8, 7, 8] {
                cmd_fig(w, &cfg, &opts)?;
            }
            let bars = report::storage(&cfg);
            println!(
                "{}",
                report::render_bars("Additional storage overhead reduction", &bars, false)
            );
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => return Err(format!("unknown command {other:?}\n\n{USAGE}")),
    }
    Ok(())
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
