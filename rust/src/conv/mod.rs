//! Convolution semantics: parameters, derived shapes and the naive
//! (loop-nest) forward/backward oracle every other path is tested against.

mod params;
mod reference;

pub use params::ConvParams;
pub use reference::{conv2d_fwd, conv2d_bwd_input, conv2d_bwd_weight};
