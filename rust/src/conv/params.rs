//! Convolution layer parameters and the paper's derived shape symbols.

/// Parameters of one convolutional layer, following the paper's Table I.
///
/// Forward: `I^{l+1} [B,N,Ho,Wo] = I^l [B,C,Hi,Wi] * W^l [N,C,Kh,Kw]`
/// with stride `S` and zero-padding `(Ph, Pw)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvParams {
    /// Batch size `B` (the paper evaluates with 2).
    pub b: usize,
    /// Input channels `C`.
    pub c: usize,
    /// Input height `Hi`.
    pub hi: usize,
    /// Input width `Wi`.
    pub wi: usize,
    /// Output channels `N`.
    pub n: usize,
    /// Kernel height `Kh`.
    pub kh: usize,
    /// Kernel width `Kw`.
    pub kw: usize,
    /// Stride `S` (same in both directions, as in the paper).
    pub s: usize,
    /// Padding in the height direction `Ph`.
    pub ph: usize,
    /// Padding in the width direction `Pw`.
    pub pw: usize,
}

impl ConvParams {
    /// Square-image, square-kernel constructor matching the paper's
    /// `Hi(Wi)/C/N/Kh(Kw)/S/Ph(Pw)` layer notation.
    pub const fn square(hi: usize, c: usize, n: usize, k: usize, s: usize, p: usize) -> Self {
        Self { b: 2, c, hi, wi: hi, n, kh: k, kw: k, s, ph: p, pw: p }
    }

    /// With a different batch size.
    pub const fn with_batch(mut self, b: usize) -> Self {
        self.b = b;
        self
    }

    /// Output height `Ho = floor((Hi + 2Ph - Kh)/S) + 1`.
    pub const fn ho(&self) -> usize {
        (self.hi + 2 * self.ph - self.kh) / self.s + 1
    }

    /// Output width `Wo`.
    pub const fn wo(&self) -> usize {
        (self.wi + 2 * self.pw - self.kw) / self.s + 1
    }

    /// `Ho'' = Ho + (Ho-1)(S-1)` — height of the zero-inserted loss map.
    pub const fn ho2(&self) -> usize {
        let ho = self.ho();
        ho + (ho - 1) * (self.s - 1)
    }

    /// `Wo'' = Wo + (Wo-1)(S-1)`.
    pub const fn wo2(&self) -> usize {
        let wo = self.wo();
        wo + (wo - 1) * (self.s - 1)
    }

    /// `Ho''' = Ho + 2(Kh-1-Ph) + (Ho-1)(S-1)` — height of the
    /// zero-inserted *and* zero-padded loss map used by loss calculation.
    pub const fn ho3(&self) -> usize {
        self.ho2() + 2 * (self.kh - 1 - self.ph)
    }

    /// `Wo''' = Wo + 2(Kw-1-Pw) + (Wo-1)(S-1)`.
    pub const fn wo3(&self) -> usize {
        self.wo2() + 2 * (self.kw - 1 - self.pw)
    }

    /// Rows of the input that actually received gradient:
    /// `(Ho-1)S + Kh - 2Ph`. Equals `Hi` when the forward floor-division
    /// is exact; otherwise the last `Hi - hi_eff` rows have zero loss.
    pub const fn hi_eff(&self) -> usize {
        (self.ho() - 1) * self.s + self.kh - 2 * self.ph
    }

    /// Column counterpart of [`Self::hi_eff`].
    pub const fn wi_eff(&self) -> usize {
        (self.wo() - 1) * self.s + self.kw - 2 * self.pw
    }

    /// Number of elements of the input `I^l`.
    pub const fn input_elems(&self) -> usize {
        self.b * self.c * self.hi * self.wi
    }

    /// Number of elements of the kernel `W^l`.
    pub const fn kernel_elems(&self) -> usize {
        self.n * self.c * self.kh * self.kw
    }

    /// Number of elements of the output / loss map `dY`.
    pub const fn output_elems(&self) -> usize {
        self.b * self.n * self.ho() * self.wo()
    }

    /// MACs of the forward convolution.
    pub const fn fwd_macs(&self) -> usize {
        self.output_elems() * self.c * self.kh * self.kw
    }

    /// GEMM dimensions `(M, K, Ncols)` of the **loss calculation**
    /// (`Tr(dX) [C x B*Hi*Wi] = A [C x N*Kh*Kw] . B [N*Kh*Kw x B*Hi*Wi]`).
    pub const fn loss_gemm_dims(&self) -> (usize, usize, usize) {
        (self.c, self.n * self.kh * self.kw, self.b * self.hi * self.wi)
    }

    /// GEMM dimensions `(M, K, Ncols)` of the **gradient calculation**
    /// (`dW [N x C*Kh*Kw] = A [N x B*Ho''*Wo''] . B [B*Ho''*Wo'' x C*Kh*Kw]`).
    pub const fn grad_gemm_dims(&self) -> (usize, usize, usize) {
        (self.n, self.b * self.ho2() * self.wo2(), self.c * self.kh * self.kw)
    }

    /// Paper-style layer id string `Hi/C/N/Kh/S/Ph`.
    pub fn id(&self) -> String {
        format!("{}/{}/{}/{}/{}/{}", self.hi, self.c, self.n, self.kh, self.s, self.ph)
    }

    /// Validity checks used by tests and the workload tables.
    pub fn validate(&self) -> Result<(), String> {
        if self.kh == 0 || self.kw == 0 || self.s == 0 || self.b == 0 || self.c == 0 || self.n == 0 {
            return Err(format!("degenerate parameter in {self:?}"));
        }
        if self.hi + 2 * self.ph < self.kh || self.wi + 2 * self.pw < self.kw {
            return Err(format!("kernel larger than padded input in {self:?}"));
        }
        if self.ph >= self.kh || self.pw >= self.kw {
            // The paper's area-0 condition (Eq. 2) assumes Kh-1-Ph >= 0.
            return Err(format!("padding >= kernel unsupported by BP-im2col in {self:?}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The five layers of Table II.
    pub const T2_LAYERS: [ConvParams; 5] = [
        ConvParams::square(224, 3, 64, 3, 2, 0),
        ConvParams::square(112, 64, 64, 3, 2, 1),
        ConvParams::square(56, 256, 512, 1, 2, 0),
        ConvParams::square(28, 244, 244, 3, 2, 1),
        ConvParams::square(14, 1024, 2048, 1, 2, 0),
    ];

    #[test]
    fn derived_shapes_layer1() {
        // 224/3/64/3/2/0: Ho = floor((224-3)/2)+1 = 111.
        let p = T2_LAYERS[0];
        assert_eq!(p.ho(), 111);
        assert_eq!(p.ho2(), 221);
        assert_eq!(p.ho3(), 225); // 221 + 2*(3-1-0)
        assert_eq!(p.hi_eff(), 223); // floor div inexact: last input row has zero loss
    }

    #[test]
    fn derived_shapes_layer2() {
        // 112/64/64/3/2/1: Ho = (112+2-3)/2+1 = 56.
        let p = T2_LAYERS[1];
        assert_eq!(p.ho(), 56);
        assert_eq!(p.ho2(), 111);
        assert_eq!(p.ho3(), 113);
        assert_eq!(p.hi_eff(), 111); // inexact again
    }

    #[test]
    fn derived_shapes_1x1() {
        // 56/256/512/1/2/0: Ho = (56-1)/2+1 = 28, K-1-P = 0 so Ho''' = Ho''.
        let p = T2_LAYERS[2];
        assert_eq!(p.ho(), 28);
        assert_eq!(p.ho2(), 55);
        assert_eq!(p.ho3(), 55);
    }

    #[test]
    fn exact_division_recovers_hi() {
        // 4/1/1/2/2/0: Ho = (4-2)/2+1 = 2, exact: hi_eff == hi.
        let p = ConvParams::square(4, 1, 1, 2, 2, 0);
        assert_eq!(p.ho(), 2);
        assert_eq!(p.hi_eff(), 4);
    }

    #[test]
    fn gemm_dims_layer1() {
        let p = T2_LAYERS[0];
        assert_eq!(p.loss_gemm_dims(), (3, 576, 2 * 224 * 224));
        assert_eq!(p.grad_gemm_dims(), (64, 2 * 221 * 221, 27));
    }

    #[test]
    fn validate_rejects_bad_padding() {
        let mut p = ConvParams::square(8, 1, 1, 1, 2, 0);
        assert!(p.validate().is_ok());
        p.ph = 1; // Ph >= Kh
        assert!(p.validate().is_err());
    }

    #[test]
    fn stride1_is_degenerate_but_consistent() {
        let p = ConvParams::square(8, 2, 2, 3, 1, 1);
        assert_eq!(p.ho(), 8);
        assert_eq!(p.ho2(), 8); // no insertion at S=1
        assert_eq!(p.ho3(), 10); // 8 + 2*(3-1-1)
    }
}
