//! Convolution layer parameters and the paper's derived shape symbols,
//! generalized to asymmetric strides, kernel dilation and grouped
//! convolution (DESIGN.md §2).

use crate::sparse::Density;

/// Parameters of one convolutional layer, following the paper's Table I
/// generalized beyond square/symmetric geometry.
///
/// Forward: `I^{l+1} [B,N,Ho,Wo] = I^l [B,C,Hi,Wi] * W^l [N,C/G,Kh,Kw]`
/// with strides `(Sh, Sw)`, zero-padding `(Ph, Pw)`, kernel dilation
/// `(Dh, Dw)` and `G` channel groups. The paper's geometry is the
/// special case `Sh == Sw`, `Dh == Dw == 1`, `G == 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvParams {
    /// Batch size `B` (the paper evaluates with 2).
    pub b: usize,
    /// Input channels `C`.
    pub c: usize,
    /// Input height `Hi`.
    pub hi: usize,
    /// Input width `Wi`.
    pub wi: usize,
    /// Output channels `N`.
    pub n: usize,
    /// Kernel height `Kh`.
    pub kh: usize,
    /// Kernel width `Kw`.
    pub kw: usize,
    /// Stride in the height direction `Sh`.
    pub sh: usize,
    /// Stride in the width direction `Sw`.
    pub sw: usize,
    /// Padding in the height direction `Ph`.
    pub ph: usize,
    /// Padding in the width direction `Pw`.
    pub pw: usize,
    /// Kernel dilation in the height direction `Dh` (1 = dense).
    pub dh: usize,
    /// Kernel dilation in the width direction `Dw` (1 = dense).
    pub dw: usize,
    /// Channel groups `G` (`C` and `N` must both divide; `G == C == N`
    /// is a depthwise convolution).
    pub groups: usize,
    /// *Data* density of the layer's values (weight and activation
    /// non-zero fractions, fixed-point thousandths — DESIGN.md §14).
    /// [`Density::DENSE`] for every pre-existing geometry; orthogonal
    /// to the *structural* zero-space the shape fields imply.
    pub density: Density,
}

impl ConvParams {
    /// Square-image, square-kernel constructor matching the paper's
    /// `Hi(Wi)/C/N/Kh(Kw)/S/Ph(Pw)` layer notation (dense, ungrouped,
    /// batch 2 as in the paper's evaluation).
    ///
    /// # Example
    ///
    /// ```
    /// use bp_im2col::ConvParams;
    ///
    /// // Table II layer 1: 224/3/64/3/2/0.
    /// let p = ConvParams::square(224, 3, 64, 3, 2, 0);
    /// assert_eq!(p.ho(), 111);  // floor((224 - 3)/2) + 1
    /// assert_eq!(p.ho2(), 221); // zero-inserted loss map
    /// assert_eq!(p.ho3(), 225); // + 2*(K-1-P) padding
    /// assert_eq!(p.id(), "224/3/64/3/2/0");
    ///
    /// // Builders cover the generalized geometry.
    /// let g = ConvParams::square(56, 128, 128, 3, 2, 1).with_groups(32);
    /// assert_eq!((g.cg(), g.ng()), (4, 4));
    /// g.validate().unwrap();
    /// ```
    pub const fn square(hi: usize, c: usize, n: usize, k: usize, s: usize, p: usize) -> Self {
        Self::basic(2, c, hi, hi, n, k, k, s, p, p)
    }

    /// Dense ungrouped layer with symmetric stride `s` — the seed
    /// geometry every pre-existing call site used.
    #[allow(clippy::too_many_arguments)]
    pub const fn basic(
        b: usize,
        c: usize,
        hi: usize,
        wi: usize,
        n: usize,
        kh: usize,
        kw: usize,
        s: usize,
        ph: usize,
        pw: usize,
    ) -> Self {
        Self {
            b,
            c,
            hi,
            wi,
            n,
            kh,
            kw,
            sh: s,
            sw: s,
            ph,
            pw,
            dh: 1,
            dw: 1,
            groups: 1,
            density: Density::DENSE,
        }
    }

    /// With a different batch size.
    pub const fn with_batch(mut self, b: usize) -> Self {
        self.b = b;
        self
    }

    /// With asymmetric strides `(Sh, Sw)`.
    pub const fn with_stride(mut self, sh: usize, sw: usize) -> Self {
        self.sh = sh;
        self.sw = sw;
        self
    }

    /// With kernel dilation `(Dh, Dw)`.
    pub const fn with_dilation(mut self, dh: usize, dw: usize) -> Self {
        self.dh = dh;
        self.dw = dw;
        self
    }

    /// With `g` channel groups.
    pub const fn with_groups(mut self, g: usize) -> Self {
        self.groups = g;
        self
    }

    /// With a sub-dense *data* density (weight and activation non-zero
    /// fractions in thousandths — see [`Density`]).
    pub const fn with_density(mut self, weight_millis: u16, act_millis: u16) -> Self {
        self.density = Density { weight_millis, act_millis };
        self
    }

    /// Effective (dilated) kernel height `Kh' = Dh(Kh-1) + 1`.
    pub const fn kh_eff(&self) -> usize {
        self.dh * (self.kh - 1) + 1
    }

    /// Effective (dilated) kernel width `Kw' = Dw(Kw-1) + 1`.
    pub const fn kw_eff(&self) -> usize {
        self.dw * (self.kw - 1) + 1
    }

    /// Input channels per group `C/G`.
    pub const fn cg(&self) -> usize {
        self.c / self.groups
    }

    /// Output channels per group `N/G`.
    pub const fn ng(&self) -> usize {
        self.n / self.groups
    }

    /// Output height `Ho = floor((Hi + 2Ph - Dh(Kh-1) - 1)/Sh) + 1`.
    pub const fn ho(&self) -> usize {
        (self.hi + 2 * self.ph - self.kh_eff()) / self.sh + 1
    }

    /// Output width `Wo`.
    pub const fn wo(&self) -> usize {
        (self.wi + 2 * self.pw - self.kw_eff()) / self.sw + 1
    }

    /// `Ho'' = Ho + (Ho-1)(Sh-1)` — height of the zero-inserted loss map.
    pub const fn ho2(&self) -> usize {
        (self.ho() - 1) * self.sh + 1
    }

    /// `Wo'' = Wo + (Wo-1)(Sw-1)`.
    pub const fn wo2(&self) -> usize {
        (self.wo() - 1) * self.sw + 1
    }

    /// Height extension of the loss-calculation padding:
    /// `Eh = Dh(Kh-1) - Ph` (the generalized `Kh-1-Ph` of Eq. 2).
    pub const fn ext_h(&self) -> usize {
        self.dh * (self.kh - 1) - self.ph
    }

    /// Width counterpart of [`Self::ext_h`].
    pub const fn ext_w(&self) -> usize {
        self.dw * (self.kw - 1) - self.pw
    }

    /// `Ho''' = Ho'' + 2(Dh(Kh-1) - Ph)` — height of the zero-inserted
    /// *and* zero-padded loss map used by loss calculation.
    pub const fn ho3(&self) -> usize {
        self.ho2() + 2 * self.ext_h()
    }

    /// `Wo''' = Wo'' + 2(Dw(Kw-1) - Pw)`.
    pub const fn wo3(&self) -> usize {
        self.wo2() + 2 * self.ext_w()
    }

    /// Rows of the input that actually received gradient:
    /// `(Ho-1)Sh + Dh(Kh-1) + 1 - 2Ph`. Equals `Hi` when the forward
    /// floor-division is exact; otherwise the last `Hi - hi_eff` rows
    /// have zero loss.
    pub const fn hi_eff(&self) -> usize {
        (self.ho() - 1) * self.sh + self.kh_eff() - 2 * self.ph
    }

    /// Column counterpart of [`Self::hi_eff`].
    pub const fn wi_eff(&self) -> usize {
        (self.wo() - 1) * self.sw + self.kw_eff() - 2 * self.pw
    }

    /// Number of elements of the input `I^l`.
    pub const fn input_elems(&self) -> usize {
        self.b * self.c * self.hi * self.wi
    }

    /// Number of elements of the kernel `W^l` (`N x C/G x Kh x Kw`).
    pub const fn kernel_elems(&self) -> usize {
        self.n * self.cg() * self.kh * self.kw
    }

    /// Number of elements of the output / loss map `dY`.
    pub const fn output_elems(&self) -> usize {
        self.b * self.n * self.ho() * self.wo()
    }

    /// MACs of the forward convolution.
    pub const fn fwd_macs(&self) -> usize {
        self.output_elems() * self.cg() * self.kh * self.kw
    }

    /// Per-group GEMM dimensions `(M, K, Ncols)` of the **loss
    /// calculation** (`Tr(dX_g) [C/G x B*Hi*Wi] = A_g [C/G x (N/G)*Kh*Kw]
    /// . B_g [(N/G)*Kh*Kw x B*Hi*Wi]`); the layer runs `G` such GEMMs.
    pub const fn loss_gemm_dims(&self) -> (usize, usize, usize) {
        (self.cg(), self.ng() * self.kh * self.kw, self.b * self.hi * self.wi)
    }

    /// Per-group GEMM dimensions `(M, K, Ncols)` of the **gradient
    /// calculation** (`dW_g [N/G x (C/G)*Kh*Kw] = A_g [N/G x B*Ho''*Wo'']
    /// . B_g [B*Ho''*Wo'' x (C/G)*Kh*Kw]`); the layer runs `G` such GEMMs.
    pub const fn grad_gemm_dims(&self) -> (usize, usize, usize) {
        (self.ng(), self.b * self.ho2() * self.wo2(), self.cg() * self.kh * self.kw)
    }

    /// Paper-style layer id string `Hi/C/N/Kh/S/Ph`, with `ShxSw` in the
    /// stride slot when asymmetric, `/dD` / `/gG` suffixes for
    /// dilated / grouped layers, and `/wNNN` / `/aNNN` suffixes
    /// (thousandths) for sub-dense weight / activation density
    /// (identical to the seed format for the paper's dense symmetric
    /// geometry).
    pub fn id(&self) -> String {
        let stride = if self.sh == self.sw {
            self.sh.to_string()
        } else {
            format!("{}x{}", self.sh, self.sw)
        };
        let mut id = format!("{}/{}/{}/{}/{}/{}", self.hi, self.c, self.n, self.kh, stride, self.ph);
        if self.dh != 1 || self.dw != 1 {
            if self.dh == self.dw {
                id.push_str(&format!("/d{}", self.dh));
            } else {
                id.push_str(&format!("/d{}x{}", self.dh, self.dw));
            }
        }
        if self.groups != 1 {
            id.push_str(&format!("/g{}", self.groups));
        }
        if self.density.weight_millis != 1000 {
            id.push_str(&format!("/w{}", self.density.weight_millis));
        }
        if self.density.act_millis != 1000 {
            id.push_str(&format!("/a{}", self.density.act_millis));
        }
        id
    }

    /// Parse a layer spec string into validated parameters.
    ///
    /// Accepts both the input form `H/C/N/K/S/P[/G[/D]]` (bare numerics,
    /// groups then dilation) and the exact strings [`ConvParams::id`]
    /// prints (`S` may be `ShxSw`; suffixes `dD`/`dDhxDw`, `gG`, and
    /// the density thousandths `wNNN`/`aNNN` in any order) — so every
    /// layer id in the tool's own output round-trips through
    /// `sim --layer`.
    ///
    /// # Example
    ///
    /// ```
    /// use bp_im2col::ConvParams;
    ///
    /// let p = ConvParams::parse_spec("56/128/128/3/2/1/g32").unwrap();
    /// assert_eq!(p.groups, 32);
    /// // Printed ids parse back to the identical geometry.
    /// assert_eq!(ConvParams::parse_spec(&p.id()).unwrap(), p);
    /// assert!(ConvParams::parse_spec("1/2/3").is_err());
    /// ```
    pub fn parse_spec(spec: &str) -> Result<Self, String> {
        let parts: Vec<&str> = spec.split('/').collect();
        if !(6..=10).contains(&parts.len()) {
            return Err(format!(
                "layer spec must be H/C/N/K/S/P[/G[/D]][/wNNN][/aNNN], got {spec:?}"
            ));
        }
        let num = |s: &str| -> Result<usize, String> {
            s.parse().map_err(|_| format!("bad layer component {s:?}"))
        };
        let (hi, c, n) = (num(parts[0])?, num(parts[1])?, num(parts[2])?);
        let (k, ph) = (num(parts[3])?, num(parts[5])?);
        let (sh, sw) = Self::parse_pair(parts[4])?;
        let mut p = ConvParams::square(hi, c, n, k, 1, ph).with_stride(sh, sw);
        let mut groups_set = false;
        let mut dilation_set = false;
        let mut weight_set = false;
        let mut act_set = false;
        let mut tagged = false;
        let millis = |rest: &str, what: &str| -> Result<u16, String> {
            let v = num(rest)?;
            if v == 0 || v > 1000 {
                return Err(format!("{what} density must be 1..=1000 thousandths in {spec:?}"));
            }
            Ok(v as u16)
        };
        for extra in &parts[6..] {
            if let Some(rest) = extra.strip_prefix('d') {
                if dilation_set {
                    return Err(format!("duplicate dilation component {extra:?} in {spec:?}"));
                }
                let (dh, dw) = Self::parse_pair(rest)?;
                p = p.with_dilation(dh, dw);
                dilation_set = true;
                tagged = true;
            } else if let Some(rest) = extra.strip_prefix('g') {
                if groups_set {
                    return Err(format!("duplicate groups component {extra:?} in {spec:?}"));
                }
                p = p.with_groups(num(rest)?);
                groups_set = true;
                tagged = true;
            } else if let Some(rest) = extra.strip_prefix('w') {
                if weight_set {
                    return Err(format!("duplicate weight-density component {extra:?} in {spec:?}"));
                }
                p.density.weight_millis = millis(rest, "weight")?;
                weight_set = true;
                tagged = true;
            } else if let Some(rest) = extra.strip_prefix('a') {
                if act_set {
                    return Err(format!("duplicate act-density component {extra:?} in {spec:?}"));
                }
                p.density.act_millis = millis(rest, "act")?;
                act_set = true;
                tagged = true;
            } else if tagged {
                // A bare numeral after a gG/dD component is ambiguous
                // (positional order is groups-then-dilation, which a tag
                // may already have consumed) — require tags throughout.
                return Err(format!(
                    "bare component {extra:?} after a tagged g/d component in {spec:?}; \
                     tag it as g{extra} or d{extra}"
                ));
            } else if !groups_set {
                p = p.with_groups(num(extra)?);
                groups_set = true;
            } else {
                let d = num(extra)?;
                p = p.with_dilation(d, d);
                dilation_set = true;
            }
        }
        p.validate()?;
        Ok(p)
    }

    /// Parse one `A` or `AxB` spec component (strides, dilation).
    fn parse_pair(s: &str) -> Result<(usize, usize), String> {
        let bad = || format!("bad layer component {s:?}");
        match s.split_once('x') {
            None => {
                let v: usize = s.parse().map_err(|_| bad())?;
                Ok((v, v))
            }
            Some((a, b)) => {
                Ok((a.parse().map_err(|_| bad())?, b.parse().map_err(|_| bad())?))
            }
        }
    }

    /// Validity checks used by tests and the workload tables.
    pub fn validate(&self) -> Result<(), String> {
        if self.kh == 0
            || self.kw == 0
            || self.sh == 0
            || self.sw == 0
            || self.dh == 0
            || self.dw == 0
            || self.b == 0
            || self.c == 0
            || self.n == 0
            || self.groups == 0
        {
            return Err(format!("degenerate parameter in {self:?}"));
        }
        self.density.validate().map_err(|e| format!("{e} in {self:?}"))?;
        // Magnitude bounds. The analytic model multiplies these
        // components freely in usize/u64/f64; without a cap, a hostile
        // spec (e.g. through the HTTP query route) wraps in release
        // builds and returns silently wrong numbers instead of an
        // error. Per-component first (so the checks below cannot
        // themselves overflow), then a combined volume bound computed
        // in u128: every quantity the model derives — zero-spaced
        // extents, MACs, traffic bytes — is a small multiple of it, so
        // capping it at 2^48 keeps all downstream arithmetic far from
        // wrap-around (and exactly representable in f64). Real
        // workloads sit near 2^32.
        const MAX_DIM: usize = 1 << 20;
        for (label, v) in [
            ("B", self.b),
            ("C", self.c),
            ("N", self.n),
            ("Hi", self.hi),
            ("Wi", self.wi),
            ("Kh", self.kh),
            ("Kw", self.kw),
            ("Sh", self.sh),
            ("Sw", self.sw),
            ("Dh", self.dh),
            ("Dw", self.dw),
            ("Ph", self.ph),
            ("Pw", self.pw),
        ] {
            if v > MAX_DIM {
                return Err(format!("{label}={v} exceeds the supported maximum {MAX_DIM}"));
            }
        }
        const MAX_VOLUME: u128 = 1 << 48;
        // Checked multiplication throughout: with components up to 2^20
        // the raw product can overflow even u128, and a wrapped product
        // sneaking under the bound would defeat the guard. Overflow IS
        // "too large".
        let hz = (self.hi + 2 * self.ph) as u128 * self.sh as u128
            + (self.dh * (self.kh - 1) + 1) as u128;
        let wz = (self.wi + 2 * self.pw) as u128 * self.sw as u128
            + (self.dw * (self.kw - 1) + 1) as u128;
        let volume = [self.c as u128, self.n as u128, hz, wz, (self.kh * self.kw) as u128]
            .iter()
            .try_fold(self.b as u128, |acc, &v| acc.checked_mul(v));
        match volume {
            Some(v) if v <= MAX_VOLUME => {}
            _ => {
                return Err(format!(
                    "layer volume (B*C*N*zero-spaced-H*W*Kh*Kw) exceeds the supported \
                     maximum 2^48 in {self:?}"
                ));
            }
        }
        if self.c % self.groups != 0 || self.n % self.groups != 0 {
            return Err(format!("groups must divide C and N in {self:?}"));
        }
        if self.hi + 2 * self.ph < self.kh_eff() || self.wi + 2 * self.pw < self.kw_eff() {
            return Err(format!("kernel larger than padded input in {self:?}"));
        }
        if self.ph > self.dh * (self.kh - 1) || self.pw > self.dw * (self.kw - 1) {
            // The generalized area-0 condition (Eq. 2) assumes
            // Dh(Kh-1) - Ph >= 0 (DESIGN.md §2).
            return Err(format!("padding > dilated kernel extent unsupported by BP-im2col in {self:?}"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The five layers of Table II.
    pub const T2_LAYERS: [ConvParams; 5] = [
        ConvParams::square(224, 3, 64, 3, 2, 0),
        ConvParams::square(112, 64, 64, 3, 2, 1),
        ConvParams::square(56, 256, 512, 1, 2, 0),
        ConvParams::square(28, 244, 244, 3, 2, 1),
        ConvParams::square(14, 1024, 2048, 1, 2, 0),
    ];

    #[test]
    fn derived_shapes_layer1() {
        // 224/3/64/3/2/0: Ho = floor((224-3)/2)+1 = 111.
        let p = T2_LAYERS[0];
        assert_eq!(p.ho(), 111);
        assert_eq!(p.ho2(), 221);
        assert_eq!(p.ho3(), 225); // 221 + 2*(3-1-0)
        assert_eq!(p.hi_eff(), 223); // floor div inexact: last input row has zero loss
    }

    #[test]
    fn derived_shapes_layer2() {
        // 112/64/64/3/2/1: Ho = (112+2-3)/2+1 = 56.
        let p = T2_LAYERS[1];
        assert_eq!(p.ho(), 56);
        assert_eq!(p.ho2(), 111);
        assert_eq!(p.ho3(), 113);
        assert_eq!(p.hi_eff(), 111); // inexact again
    }

    #[test]
    fn derived_shapes_1x1() {
        // 56/256/512/1/2/0: Ho = (56-1)/2+1 = 28, K-1-P = 0 so Ho''' = Ho''.
        let p = T2_LAYERS[2];
        assert_eq!(p.ho(), 28);
        assert_eq!(p.ho2(), 55);
        assert_eq!(p.ho3(), 55);
    }

    #[test]
    fn exact_division_recovers_hi() {
        // 4/1/1/2/2/0: Ho = (4-2)/2+1 = 2, exact: hi_eff == hi.
        let p = ConvParams::square(4, 1, 1, 2, 2, 0);
        assert_eq!(p.ho(), 2);
        assert_eq!(p.hi_eff(), 4);
    }

    #[test]
    fn gemm_dims_layer1() {
        let p = T2_LAYERS[0];
        assert_eq!(p.loss_gemm_dims(), (3, 576, 2 * 224 * 224));
        assert_eq!(p.grad_gemm_dims(), (64, 2 * 221 * 221, 27));
    }

    #[test]
    fn validate_rejects_bad_padding() {
        let mut p = ConvParams::square(8, 1, 1, 1, 2, 0);
        assert!(p.validate().is_ok());
        p.ph = 1; // Ph > Dh(Kh-1)
        assert!(p.validate().is_err());
    }

    #[test]
    fn stride1_is_degenerate_but_consistent() {
        let p = ConvParams::square(8, 2, 2, 3, 1, 1);
        assert_eq!(p.ho(), 8);
        assert_eq!(p.ho2(), 8); // no insertion at S=1
        assert_eq!(p.ho3(), 10); // 8 + 2*(3-1-1)
    }

    #[test]
    fn asymmetric_stride_shapes() {
        // 9x12 input, 3x3 kernel, stride (2, 3), pad 1.
        let p =
            ConvParams::basic(1, 1, 9, 12, 1, 3, 3, 1, 1, 1).with_stride(2, 3);
        assert_eq!(p.ho(), 5); // (9+2-3)/2+1
        assert_eq!(p.wo(), 4); // (12+2-3)/3+1
        assert_eq!(p.ho2(), 9);
        assert_eq!(p.wo2(), 10);
        assert_eq!(p.id(), "9/1/1/3/2x3/1");
    }

    #[test]
    fn dilated_shapes() {
        // DeepLab-style: 3x3 kernel, dilation 2, "same" padding 2, stride 1.
        let p = ConvParams::square(28, 4, 4, 3, 1, 2).with_dilation(2, 2);
        assert_eq!(p.kh_eff(), 5);
        assert_eq!(p.ho(), 28); // (28+4-5)/1+1
        assert_eq!(p.ext_h(), 2); // Dh(Kh-1)-Ph = 4-2
        assert_eq!(p.ho3(), 32);
        assert_eq!(p.id(), "28/4/4/3/1/2/d2");
        p.validate().unwrap();
    }

    #[test]
    fn grouped_dims() {
        let p = ConvParams::square(56, 128, 128, 3, 2, 1).with_groups(32);
        assert_eq!((p.cg(), p.ng()), (4, 4));
        assert_eq!(p.kernel_elems(), 128 * 4 * 9);
        assert_eq!(p.loss_gemm_dims(), (4, 36, 2 * 56 * 56));
        assert_eq!(p.grad_gemm_dims(), (4, 2 * p.ho2() * p.wo2(), 36));
        assert_eq!(p.id(), "56/128/128/3/2/1/g32");
        p.validate().unwrap();
    }

    #[test]
    fn depthwise_is_groups_eq_channels() {
        let p = ConvParams::square(112, 64, 64, 3, 2, 1).with_groups(64);
        assert_eq!((p.cg(), p.ng()), (1, 1));
        p.validate().unwrap();
    }

    #[test]
    fn validate_rejects_nondividing_groups() {
        let p = ConvParams::square(56, 6, 8, 3, 2, 1).with_groups(4);
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_oversized_geometry() {
        // Single huge component: caught by the per-component cap (and
        // before any subtraction that could wrap).
        let p = ConvParams::square(usize::MAX / 2, 1, 1, 1, 1, 0);
        let err = p.validate().unwrap_err();
        assert!(err.contains("maximum"), "{err}");
        // Every component under the cap but the combined volume huge:
        // caught by the u128 volume bound.
        let p = ConvParams::square(1 << 14, 1 << 12, 1 << 12, 3, 2, 1);
        let err = p.validate().unwrap_err();
        assert!(err.contains("volume"), "{err}");
        // The largest real workloads stay comfortably inside.
        for net in crate::workloads::extended_networks() {
            for l in &net.layers {
                l.params.validate().unwrap_or_else(|e| panic!("{}/{}: {e}", net.name, l.name));
            }
        }
    }

    #[test]
    fn validate_rejects_overwide_dilated_padding() {
        // Ph = 3 > Dh(Kh-1) = 2 breaks the generalized Eq. 2.
        let mut p = ConvParams::square(28, 4, 4, 3, 1, 2).with_dilation(1, 1);
        p.ph = 3;
        assert!(p.validate().is_err());
    }

    #[test]
    fn parse_spec_accepts_input_and_printed_forms() {
        // Positional groups-then-dilation, tagged g/d in either order,
        // asymmetric pairs — and every printed id round-trips.
        let cases = [
            ("224/3/64/3/2/0", ConvParams::square(224, 3, 64, 3, 2, 0)),
            ("56/128/128/3/2/1/32", ConvParams::square(56, 128, 128, 3, 2, 1).with_groups(32)),
            (
                "28/64/64/3/1/2/64/2",
                ConvParams::square(28, 64, 64, 3, 1, 2).with_groups(64).with_dilation(2, 2),
            ),
            (
                "28/64/64/3/1/2/d2/g64",
                ConvParams::square(28, 64, 64, 3, 1, 2).with_groups(64).with_dilation(2, 2),
            ),
            ("9/1/1/3/2x3/1", ConvParams::square(9, 1, 1, 3, 1, 1).with_stride(2, 3)),
        ];
        for (spec, want) in cases {
            let got = ConvParams::parse_spec(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(got, want, "{spec}");
            assert_eq!(ConvParams::parse_spec(&got.id()).unwrap(), got, "{spec} id round-trip");
        }
    }

    #[test]
    fn parse_spec_rejects_malformed_and_invalid() {
        let bad_specs =
            ["1/2/3", "a/b/c/d/e/f", "224/3/64/3/0/0", "8/1/1/1/2/3", "56/100/100/3/2/1/32"];
        for bad in bad_specs {
            assert!(ConvParams::parse_spec(bad).is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn parse_spec_rejects_bare_component_after_tagged() {
        // `g64/2` would silently overwrite groups (2 divides 64), and
        // `d2/64` would misread 64 as groups — both ambiguous mixes.
        for bad in ["28/64/64/3/1/2/g64/2", "28/64/64/3/1/2/d2/64"] {
            let err = ConvParams::parse_spec(bad).unwrap_err();
            assert!(err.contains("tagged"), "{bad}: {err}");
        }
        // Positional-then-tagged dilation stays unambiguous and accepted.
        let p = ConvParams::parse_spec("28/64/64/3/1/2/64/d2").unwrap();
        assert_eq!((p.groups, p.dh), (64, 2));
    }

    #[test]
    fn parse_spec_rejects_component_overwrites() {
        // Last-wins would silently drop what the user asked for: a tag
        // re-setting a positionally-set groups, or a repeated tag.
        for (bad, what) in [
            ("28/64/64/3/1/2/64/g32", "groups"),
            ("28/64/64/3/1/2/g4/g8", "groups"),
            ("28/64/64/3/1/2/d2/d3", "dilation"),
        ] {
            let err = ConvParams::parse_spec(bad).unwrap_err();
            assert!(err.contains("duplicate") && err.contains(what), "{bad}: {err}");
        }
    }

    #[test]
    fn density_suffixes_round_trip_and_validate() {
        // Dense layers keep the seed id format exactly.
        let dense = ConvParams::square(224, 3, 64, 3, 2, 0);
        assert_eq!(dense.id(), "224/3/64/3/2/0");
        assert_eq!(dense.density, crate::sparse::Density::DENSE);
        // Sub-dense layers append /wNNN and/or /aNNN and round-trip.
        let p = ConvParams::square(224, 3, 64, 3, 2, 0).with_density(250, 600);
        assert_eq!(p.id(), "224/3/64/3/2/0/w250/a600");
        assert_eq!(ConvParams::parse_spec(&p.id()).unwrap(), p);
        let w_only = ConvParams::square(56, 128, 128, 3, 2, 1).with_groups(32).with_density(125, 1000);
        assert_eq!(w_only.id(), "56/128/128/3/2/1/g32/w125");
        assert_eq!(ConvParams::parse_spec(&w_only.id()).unwrap(), w_only);
        // Tags compose in any order with g/d.
        let p2 = ConvParams::parse_spec("28/64/64/3/1/2/a500/d2/g64/w250").unwrap();
        assert_eq!(p2.density, crate::sparse::Density::new(250, 500).unwrap());
        assert_eq!((p2.groups, p2.dh), (64, 2));
        // Domain and duplicate rejection.
        assert!(ConvParams::parse_spec("224/3/64/3/2/0/w0").is_err(), "zero density");
        assert!(ConvParams::parse_spec("224/3/64/3/2/0/w1001").is_err(), "over-dense");
        let err = ConvParams::parse_spec("224/3/64/3/2/0/w250/w500").unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
        let err = ConvParams::parse_spec("224/3/64/3/2/0/a250/a500").unwrap_err();
        assert!(err.contains("duplicate"), "{err}");
        // validate() rejects an out-of-domain density set directly.
        let mut bad = dense;
        bad.density.weight_millis = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn seed_geometry_helpers_agree() {
        let a = ConvParams::square(28, 4, 8, 3, 2, 1);
        let b = ConvParams::basic(2, 4, 28, 28, 8, 3, 3, 2, 1, 1);
        assert_eq!(a, b);
        assert_eq!(a.id(), "28/4/8/3/2/1");
    }
}
