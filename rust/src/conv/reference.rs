//! Naive loop-nest convolution oracle.
//!
//! These are the *mathematical* definitions — O(B·N·(C/G)·Ho·Wo·Kh·Kw)
//! direct loops with no lowering, covering asymmetric strides, kernel
//! dilation and grouped convolution. Every im2col path (explicit,
//! implicit, Pallas) is checked against them.

use crate::conv::ConvParams;
use crate::tensor::Tensor4;

/// Forward convolution:
/// `Y[b,n,ho,wo] = sum_{c',kh,kw} X[b, g*C/G+c', ho*Sh+kh*Dh-Ph, wo*Sw+kw*Dw-Pw] * W[n,c',kh,kw]`
/// where `g = n / (N/G)` is the channel group of output channel `n`.
pub fn conv2d_fwd(x: &Tensor4, w: &Tensor4, p: &ConvParams) -> Tensor4 {
    assert_eq!(x.dims, [p.b, p.c, p.hi, p.wi], "input shape mismatch");
    assert_eq!(w.dims, [p.n, p.cg(), p.kh, p.kw], "kernel shape mismatch");
    let (ho, wo) = (p.ho(), p.wo());
    let (cg, ng) = (p.cg(), p.ng());
    let mut y = Tensor4::zeros([p.b, p.n, ho, wo]);
    for b in 0..p.b {
        for n in 0..p.n {
            let c_base = (n / ng) * cg;
            for oh in 0..ho {
                for ow in 0..wo {
                    let mut acc = 0.0;
                    for c in 0..cg {
                        for kh in 0..p.kh {
                            for kw in 0..p.kw {
                                let ih = (oh * p.sh + kh * p.dh) as isize - p.ph as isize;
                                let iw = (ow * p.sw + kw * p.dw) as isize - p.pw as isize;
                                acc += x.get_padded(b, c_base + c, ih, iw) * w[(n, c, kh, kw)];
                            }
                        }
                    }
                    y[(b, n, oh, ow)] = acc;
                }
            }
        }
    }
    y
}

/// Loss of the input: `dX[b,c,ih,iw] = sum_{n,kh,kw : valid} dY[b,n,ho,wo] * W[n,c',kh,kw]`
/// where `ho*Sh + kh*Dh - Ph == ih`, `wo*Sw + kw*Dw - Pw == iw`, and `n`
/// ranges over the channel group of `c`.
///
/// This is the direct adjoint of [`conv2d_fwd`] — no transposed-convolution
/// lowering, so it is immune to the zero-space bookkeeping the paper is
/// about, making it a trustworthy oracle.
pub fn conv2d_bwd_input(dy: &Tensor4, w: &Tensor4, p: &ConvParams) -> Tensor4 {
    let (ho, wo) = (p.ho(), p.wo());
    assert_eq!(dy.dims, [p.b, p.n, ho, wo], "loss shape mismatch");
    assert_eq!(w.dims, [p.n, p.cg(), p.kh, p.kw], "kernel shape mismatch");
    let (cg, ng) = (p.cg(), p.ng());
    let mut dx = Tensor4::zeros([p.b, p.c, p.hi, p.wi]);
    for b in 0..p.b {
        for n in 0..p.n {
            let c_base = (n / ng) * cg;
            for oh in 0..ho {
                for ow in 0..wo {
                    let g = dy[(b, n, oh, ow)];
                    if g == 0.0 {
                        continue;
                    }
                    for c in 0..cg {
                        for kh in 0..p.kh {
                            for kw in 0..p.kw {
                                let ih = (oh * p.sh + kh * p.dh) as isize - p.ph as isize;
                                let iw = (ow * p.sw + kw * p.dw) as isize - p.pw as isize;
                                if ih >= 0 && iw >= 0 && (ih as usize) < p.hi && (iw as usize) < p.wi {
                                    dx[(b, c_base + c, ih as usize, iw as usize)] +=
                                        g * w[(n, c, kh, kw)];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    dx
}

/// Gradient of the kernel:
/// `dW[n,c',kh,kw] = sum_{b,ho,wo} dY[b,n,ho,wo] * X[b, g*C/G+c', ho*Sh+kh*Dh-Ph, wo*Sw+kw*Dw-Pw]`.
pub fn conv2d_bwd_weight(x: &Tensor4, dy: &Tensor4, p: &ConvParams) -> Tensor4 {
    let (ho, wo) = (p.ho(), p.wo());
    assert_eq!(x.dims, [p.b, p.c, p.hi, p.wi], "input shape mismatch");
    assert_eq!(dy.dims, [p.b, p.n, ho, wo], "loss shape mismatch");
    let (cg, ng) = (p.cg(), p.ng());
    let mut dw = Tensor4::zeros([p.n, cg, p.kh, p.kw]);
    for b in 0..p.b {
        for n in 0..p.n {
            let c_base = (n / ng) * cg;
            for oh in 0..ho {
                for ow in 0..wo {
                    let g = dy[(b, n, oh, ow)];
                    if g == 0.0 {
                        continue;
                    }
                    for c in 0..cg {
                        for kh in 0..p.kh {
                            for kw in 0..p.kw {
                                let ih = (oh * p.sh + kh * p.dh) as isize - p.ph as isize;
                                let iw = (ow * p.sw + kw * p.dw) as isize - p.pw as isize;
                                dw[(n, c, kh, kw)] += g * x.get_padded(b, c_base + c, ih, iw);
                            }
                        }
                    }
                }
            }
        }
    }
    dw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn setup(p: &ConvParams, seed: u64) -> (Tensor4, Tensor4, Tensor4) {
        let mut rng = Rng::new(seed);
        let x = Tensor4::random([p.b, p.c, p.hi, p.wi], &mut rng);
        let w = Tensor4::random([p.n, p.cg(), p.kh, p.kw], &mut rng);
        let dy = Tensor4::random([p.b, p.n, p.ho(), p.wo()], &mut rng);
        (x, w, dy)
    }

    #[test]
    fn fwd_identity_kernel() {
        // 1x1 kernel of ones with stride 1 is the identity per channel.
        let p = ConvParams::basic(1, 1, 4, 4, 1, 1, 1, 1, 0, 0);
        let x = Tensor4::from_fn([1, 1, 4, 4], |_, _, h, w| (h * 4 + w) as f32);
        let w = Tensor4::from_fn([1, 1, 1, 1], |_, _, _, _| 1.0);
        assert_eq!(conv2d_fwd(&x, &w, &p), x);
    }

    #[test]
    fn fwd_known_values_stride2() {
        // 4x4 input, 2x2 ones kernel, stride 2 -> non-overlapping 2x2 sums.
        let p = ConvParams::basic(1, 1, 4, 4, 1, 2, 2, 2, 0, 0);
        let x = Tensor4::from_fn([1, 1, 4, 4], |_, _, h, w| (h * 4 + w) as f32);
        let w = Tensor4::from_fn([1, 1, 2, 2], |_, _, _, _| 1.0);
        let y = conv2d_fwd(&x, &w, &p);
        assert_eq!(y.dims, [1, 1, 2, 2]);
        assert_eq!(y.data, vec![0. + 1. + 4. + 5., 2. + 3. + 6. + 7., 8. + 9. + 12. + 13., 10. + 11. + 14. + 15.]);
    }

    #[test]
    fn fwd_dilated_equals_inflated_kernel() {
        // A dilated conv equals a dense conv with the zero-inflated kernel.
        let p = ConvParams::basic(1, 1, 9, 9, 1, 3, 3, 1, 2, 2).with_dilation(2, 2);
        let mut rng = Rng::new(77);
        let x = Tensor4::random([1, 1, 9, 9], &mut rng);
        let w = Tensor4::random([1, 1, 3, 3], &mut rng);
        let y = conv2d_fwd(&x, &w, &p);
        // Inflate the kernel to 5x5 with zeros at the odd taps.
        let w5 = Tensor4::from_fn([1, 1, 5, 5], |_, _, h, ww| {
            if h % 2 == 0 && ww % 2 == 0 { w[(0, 0, h / 2, ww / 2)] } else { 0.0 }
        });
        let pd = ConvParams::basic(1, 1, 9, 9, 1, 5, 5, 1, 2, 2);
        let yd = conv2d_fwd(&x, &w5, &pd);
        assert!(y.max_abs_diff(&yd) < 1e-5);
    }

    #[test]
    fn fwd_grouped_equals_per_group_dense() {
        // groups=2: each output-channel half sees only its input half.
        let p = ConvParams::basic(1, 4, 6, 6, 4, 3, 3, 1, 1, 1).with_groups(2);
        let (x, w, _) = setup(&p, 78);
        let y = conv2d_fwd(&x, &w, &p);
        for g in 0..2 {
            let pg = ConvParams::basic(1, 2, 6, 6, 2, 3, 3, 1, 1, 1);
            let xg = Tensor4::from_fn([1, 2, 6, 6], |b, c, h, ww| x[(b, 2 * g + c, h, ww)]);
            let wg = Tensor4::from_fn([2, 2, 3, 3], |n, c, h, ww| w[(2 * g + n, c, h, ww)]);
            let yg = conv2d_fwd(&xg, &wg, &pg);
            for n in 0..2 {
                for h in 0..p.ho() {
                    for ww in 0..p.wo() {
                        assert_eq!(y[(0, 2 * g + n, h, ww)], yg[(0, n, h, ww)]);
                    }
                }
            }
        }
    }

    /// <dY, conv(X)> == <dX, X> — the adjoint test that pins bwd_input to fwd.
    fn adjoint_identity_input(p: ConvParams, seed: u64) {
        let (x, w, dy) = setup(&p, seed);
        let y = conv2d_fwd(&x, &w, &p);
        let dx = conv2d_bwd_input(&dy, &w, &p);
        let lhs: f64 = y.data.iter().zip(&dy.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = x.data.iter().zip(&dx.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{p:?}: {lhs} vs {rhs}");
    }

    /// <dY, conv(W)> == <dW, W> — pins bwd_weight to fwd.
    fn adjoint_identity_weight(p: ConvParams, seed: u64) {
        let (x, w, dy) = setup(&p, seed);
        let y = conv2d_fwd(&x, &w, &p);
        let dw = conv2d_bwd_weight(&x, &dy, &p);
        let lhs: f64 = y.data.iter().zip(&dy.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = w.data.iter().zip(&dw.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{p:?}: {lhs} vs {rhs}");
    }

    #[test]
    fn adjoint_small_stride2() {
        let p = ConvParams::basic(2, 3, 9, 9, 4, 3, 3, 2, 1, 1);
        adjoint_identity_input(p, 1);
        adjoint_identity_weight(p, 2);
    }

    #[test]
    fn adjoint_1x1_stride2() {
        let p = ConvParams::basic(1, 4, 8, 8, 5, 1, 1, 2, 0, 0);
        adjoint_identity_input(p, 3);
        adjoint_identity_weight(p, 4);
    }

    #[test]
    fn adjoint_stride3_asymmetric() {
        let p = ConvParams::basic(1, 2, 11, 7, 3, 3, 2, 3, 1, 0);
        adjoint_identity_input(p, 5);
        adjoint_identity_weight(p, 6);
    }

    #[test]
    fn adjoint_inexact_floor_division() {
        // (10 - 3) / 2 + 1 = 4, (4-1)*2+3 = 9 < 10: last row/col uncovered.
        let p = ConvParams::basic(1, 2, 10, 10, 2, 3, 3, 2, 0, 0);
        assert!(p.hi_eff() < p.hi);
        adjoint_identity_input(p, 7);
        adjoint_identity_weight(p, 8);
    }

    #[test]
    fn adjoint_asymmetric_stride() {
        let p = ConvParams::basic(1, 2, 9, 12, 3, 3, 3, 1, 1, 1).with_stride(2, 3);
        adjoint_identity_input(p, 9);
        adjoint_identity_weight(p, 10);
    }

    #[test]
    fn adjoint_dilated() {
        let p = ConvParams::basic(1, 2, 11, 11, 2, 3, 3, 1, 2, 2).with_dilation(2, 2);
        adjoint_identity_input(p, 11);
        adjoint_identity_weight(p, 12);
    }

    #[test]
    fn adjoint_grouped_and_depthwise() {
        let g = ConvParams::basic(2, 4, 9, 9, 6, 3, 3, 2, 1, 1).with_groups(2);
        adjoint_identity_input(g, 13);
        adjoint_identity_weight(g, 14);
        let dw = ConvParams::basic(1, 4, 9, 9, 4, 3, 3, 2, 1, 1).with_groups(4);
        adjoint_identity_input(dw, 15);
        adjoint_identity_weight(dw, 16);
    }

    #[test]
    fn bwd_input_uncovered_rows_are_zero() {
        let p = ConvParams::basic(1, 1, 10, 10, 1, 3, 3, 2, 0, 0);
        let (_, w, dy) = setup(&p, 9);
        let dx = conv2d_bwd_input(&dy, &w, &p);
        for wi in 0..p.wi {
            assert_eq!(dx[(0, 0, 9, wi)], 0.0, "uncovered input row must get zero loss");
        }
    }
}
