//! Observability layer: deterministic virtual-time timelines and
//! wall-clock host profiling, two clocks kept strictly apart
//! (DESIGN.md §16).
//!
//! The repo models *virtual* time (simulated accelerator cycles) and
//! runs on *host* time (the wall clock of the machine executing the
//! simulator). Mixing the two destroys reproducibility, so this module
//! splits observability along that exact line:
//!
//! * [`timeline`] — **virtual time only.** Hierarchical spans over the
//!   deterministic fleet replay (one span per (layer, pass) job on its
//!   device track, phase and address-generation child spans, steal/idle
//!   instant events), merged in stable (device, start, job-id) order
//!   and exported as Chrome trace-event JSON that Perfetto loads
//!   directly. Timelines are *artifacts*: pure functions of (workloads,
//!   config), bit-identical run to run, across device widths, and
//!   across the CLI and HTTP frontends — so they are cacheable and
//!   `cmp`-able in CI.
//! * [`profile`] — **wall-clock only.** A global, lock-free registry of
//!   scoped timers around the host hot paths (plan-cache build phases,
//!   DSE candidate evaluation). Profiles are *telemetry*: they differ
//!   run to run by construction, are never cached, and never feed any
//!   byte-stable artifact. `profile` is the single module outside
//!   `server/` permitted to read the host clock — the
//!   `wall-clock-in-model` lint rule carves out exactly this file and
//!   nothing else.
//!
//! The split is structural, not conventional: `timeline` has no access
//! to `std::time`, and any other model/artifact file that touches the
//! host clock fails `repro lint` (and CI) immediately.

pub mod profile;
pub mod timeline;
