//! Deterministic virtual-time timelines (the artifact side of the
//! two-clock rule, DESIGN.md §16).
//!
//! A [`Timeline`] is a set of hierarchical spans over *simulated*
//! cycles: every `ts`/`dur` in it comes from the fleet's deterministic
//! virtual-time replay, never from the host clock (this module cannot
//! even name `std::time` without failing `repro lint`). Spans are
//! collected into per-device [`TrackBuffer`]s during replay and merged
//! in stable `(process, device, start, depth, job-id)` order, so the
//! exported bytes are identical run to run, across device widths, and
//! across the CLI and HTTP frontends.
//!
//! The export format is Chrome trace-event JSON — an object with a
//! `traceEvents` array of `ph:"M"` metadata records (process/thread
//! names), `ph:"X"` complete spans (`ts` + `dur`), and `ph:"i"` instant
//! events — which `chrome://tracing` and [Perfetto](https://ui.perfetto.dev)
//! load directly. One virtual cycle is mapped to one microsecond of
//! trace time, the unit Chrome's `ts` field natively speaks.

use std::fmt::Write as _;

/// A typed argument attached to a span or marker, rendered into the
/// Chrome event's `args` object.
#[derive(Clone, Debug, PartialEq)]
pub enum ArgValue {
    /// Integer payload (counts, byte totals, device indices).
    Int(i64),
    /// Float payload (virtual cycles).
    Float(f64),
    /// String payload (strategy names, layer labels).
    Text(String),
}

impl ArgValue {
    fn render(&self, out: &mut String) {
        match self {
            ArgValue::Int(v) => {
                let _ = write!(out, "{v}");
            }
            ArgValue::Float(v) => {
                let _ = write!(out, "{v}");
            }
            ArgValue::Text(s) => json_string(s, out),
        }
    }
}

/// One complete span (`ph:"X"`) on a `(process, device)` track.
///
/// `depth` encodes the hierarchy level (0 = job, 1 = phase child,
/// 2 = address-generation stage grandchild) and only serves as a merge
/// tiebreak: children share their parent's start cycle and must sort
/// after it.
#[derive(Clone, Debug)]
pub struct Span {
    /// Process (network) index within the timeline.
    pub pid: usize,
    /// Device track within the process.
    pub tid: usize,
    /// Start, in virtual cycles.
    pub ts: f64,
    /// Duration, in virtual cycles.
    pub dur: f64,
    /// Display name (layer + pass, phase name, or pipeline stage).
    pub name: String,
    /// Category: `"job"`, `"phase"`, `"addrgen-dyn"`, `"addrgen-stat"`.
    pub cat: &'static str,
    /// Id of the job the span belongs to (merge tiebreak + grouping).
    pub job_id: usize,
    /// Hierarchy level (0 = job span, deeper = finer).
    pub depth: usize,
    /// Typed annotations (strategy, metric components, ...).
    pub args: Vec<(&'static str, ArgValue)>,
}

/// One instant event (`ph:"i"`, thread-scoped): steal and idle markers.
#[derive(Clone, Debug)]
pub struct Marker {
    /// Process (network) index within the timeline.
    pub pid: usize,
    /// Device track within the process.
    pub tid: usize,
    /// Instant, in virtual cycles.
    pub ts: f64,
    /// Display name (`"steal"`, `"idle"`).
    pub name: &'static str,
    /// Id of the related job (`usize::MAX` for device-level markers).
    pub job_id: usize,
    /// Typed annotations (source device, idle cycles, ...).
    pub args: Vec<(&'static str, ArgValue)>,
}

/// Per-device collection buffer. The replay appends each device's spans
/// and markers here in execution order; [`Timeline::merge`] then folds
/// every buffer into the stable global order. Keeping collection
/// per-track means a future parallel replay can record without
/// synchronization and still merge deterministically.
#[derive(Clone, Debug)]
pub struct TrackBuffer {
    /// Process (network) index the buffer belongs to.
    pub pid: usize,
    /// Device track the buffer records.
    pub tid: usize,
    /// Spans recorded on this track, in execution order.
    pub spans: Vec<Span>,
    /// Instant events recorded on this track, in execution order.
    pub markers: Vec<Marker>,
}

impl TrackBuffer {
    /// Empty buffer for device `tid` of process `pid`.
    pub fn new(pid: usize, tid: usize) -> Self {
        Self { pid, tid, spans: Vec::new(), markers: Vec::new() }
    }

    /// Record a span on this track (pid/tid are filled in).
    pub fn span(
        &mut self,
        ts: f64,
        dur: f64,
        name: String,
        cat: &'static str,
        job_id: usize,
        depth: usize,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        let (pid, tid) = (self.pid, self.tid);
        self.spans.push(Span { pid, tid, ts, dur, name, cat, job_id, depth, args });
    }

    /// Record an instant event on this track.
    pub fn marker(
        &mut self,
        ts: f64,
        name: &'static str,
        job_id: usize,
        args: Vec<(&'static str, ArgValue)>,
    ) {
        let (pid, tid) = (self.pid, self.tid);
        self.markers.push(Marker { pid, tid, ts, name, job_id, args });
    }
}

/// A merged multi-process timeline: one process per network, one thread
/// track per simulated device.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    processes: Vec<String>,
    spans: Vec<Span>,
    markers: Vec<Marker>,
}

impl Timeline {
    /// Empty timeline.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a process (network) and return its pid.
    pub fn add_process(&mut self, name: &str) -> usize {
        self.processes.push(name.to_string());
        self.processes.len() - 1
    }

    /// Fold per-device buffers into the timeline, then restore the
    /// stable global order: spans by `(pid, tid, ts, depth, job_id)`,
    /// markers by `(pid, tid, ts, name)`. Stable-sorting after every
    /// merge makes the final byte stream independent of buffer arrival
    /// order.
    pub fn merge(&mut self, buffers: Vec<TrackBuffer>) {
        for buf in buffers {
            self.spans.extend(buf.spans);
            self.markers.extend(buf.markers);
        }
        self.spans.sort_by(|a, b| {
            (a.pid, a.tid)
                .cmp(&(b.pid, b.tid))
                .then(a.ts.total_cmp(&b.ts))
                .then((a.depth, a.job_id).cmp(&(b.depth, b.job_id)))
        });
        self.markers.sort_by(|a, b| {
            (a.pid, a.tid)
                .cmp(&(b.pid, b.tid))
                .then(a.ts.total_cmp(&b.ts))
                .then(a.name.cmp(b.name))
        });
    }

    /// Registered process names, pid-ordered.
    pub fn processes(&self) -> &[String] {
        &self.processes
    }

    /// Merged spans in stable global order.
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Merged instant events in stable global order.
    pub fn markers(&self) -> &[Marker] {
        &self.markers
    }

    /// Distinct `(pid, tid)` tracks, in order.
    fn tracks(&self) -> Vec<(usize, usize)> {
        let mut tracks: Vec<(usize, usize)> = Vec::new();
        for s in &self.spans {
            if !tracks.contains(&(s.pid, s.tid)) {
                tracks.push((s.pid, s.tid));
            }
        }
        for m in &self.markers {
            if !tracks.contains(&(m.pid, m.tid)) {
                tracks.push((m.pid, m.tid));
            }
        }
        tracks.sort_unstable();
        tracks
    }

    /// Export as Chrome trace-event JSON: metadata records first
    /// (process and thread names), then complete spans, then instant
    /// events — each group in the timeline's stable order, so the bytes
    /// are a pure function of the merged content.
    pub fn to_chrome_json(&self) -> String {
        let mut out = String::with_capacity(256 + 160 * (self.spans.len() + self.markers.len()));
        out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        let mut first = true;
        for (pid, name) in self.processes.iter().enumerate() {
            sep(&mut out, &mut first);
            let _ = write!(
                out,
                "{{\"ph\":\"M\",\"pid\":{pid},\"name\":\"process_name\",\"args\":{{\"name\":"
            );
            json_string(name, &mut out);
            out.push_str("}}");
        }
        for (pid, tid) in self.tracks() {
            sep(&mut out, &mut first);
            let _ = write!(
                out,
                "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"device {tid}\"}}}}"
            );
        }
        for s in &self.spans {
            sep(&mut out, &mut first);
            let _ = write!(
                out,
                "{{\"ph\":\"X\",\"pid\":{},\"tid\":{},\"ts\":{},\"dur\":{},\"cat\":\"{}\",\
                 \"name\":",
                s.pid, s.tid, s.ts, s.dur, s.cat
            );
            json_string(&s.name, &mut out);
            render_args(&s.args, &mut out);
            out.push('}');
        }
        for m in &self.markers {
            sep(&mut out, &mut first);
            let _ = write!(
                out,
                "{{\"ph\":\"i\",\"s\":\"t\",\"pid\":{},\"tid\":{},\"ts\":{},\"name\":",
                m.pid, m.tid, m.ts
            );
            json_string(m.name, &mut out);
            render_args(&m.args, &mut out);
            out.push('}');
        }
        out.push_str("]}");
        out
    }
}

fn sep(out: &mut String, first: &mut bool) {
    if !*first {
        out.push(',');
    }
    *first = false;
}

fn render_args(args: &[(&'static str, ArgValue)], out: &mut String) {
    if args.is_empty() {
        return;
    }
    out.push_str(",\"args\":{");
    for i in 0..args.len() {
        if i > 0 {
            out.push(',');
        }
        json_string(args[i].0, out);
        out.push(':');
        args[i].1.render(out);
    }
    out.push('}');
}

/// Minimal JSON string escape (quotes, backslashes, control bytes) —
/// span names carry layer labels and pipeline-stage formulas, which may
/// contain quotes one day but never need full Unicode escaping.
fn json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> Timeline {
        let mut tl = Timeline::new();
        let pid = tl.add_process("net-a");
        let mut d1 = TrackBuffer::new(pid, 1);
        let strategy = vec![("strategy", ArgValue::Text("bp".into()))];
        d1.span(0.0, 5.0, "l1 loss".into(), "job", 1, 0, strategy);
        let mut d0 = TrackBuffer::new(pid, 0);
        d0.span(0.0, 10.0, "l0 loss".into(), "job", 0, 0, vec![]);
        d0.span(0.0, 4.0, "compute".into(), "phase", 0, 1, vec![("cycles", ArgValue::Float(4.0))]);
        d1.marker(5.0, "idle", usize::MAX, vec![("idle_cycles", ArgValue::Float(5.0))]);
        tl.merge(vec![d1, d0]);
        tl
    }

    #[test]
    fn merge_restores_stable_global_order() {
        let tl = demo();
        let order: Vec<(usize, usize, usize)> =
            tl.spans().iter().map(|s| (s.tid, s.depth, s.job_id)).collect();
        // Device 0 before device 1; parent (depth 0) before its child.
        assert_eq!(order, vec![(0, 0, 0), (0, 1, 0), (1, 0, 1)]);
    }

    #[test]
    fn merge_is_buffer_order_invariant() {
        let a = demo().to_chrome_json();
        // Same content, buffers delivered in the opposite order.
        let mut tl = Timeline::new();
        let pid = tl.add_process("net-a");
        let mut d0 = TrackBuffer::new(pid, 0);
        d0.span(0.0, 10.0, "l0 loss".into(), "job", 0, 0, vec![]);
        d0.span(0.0, 4.0, "compute".into(), "phase", 0, 1, vec![("cycles", ArgValue::Float(4.0))]);
        let mut d1 = TrackBuffer::new(pid, 1);
        let strategy = vec![("strategy", ArgValue::Text("bp".into()))];
        d1.span(0.0, 5.0, "l1 loss".into(), "job", 1, 0, strategy);
        d1.marker(5.0, "idle", usize::MAX, vec![("idle_cycles", ArgValue::Float(5.0))]);
        tl.merge(vec![d0, d1]);
        assert_eq!(tl.to_chrome_json(), a);
    }

    #[test]
    fn chrome_export_shape() {
        let json = demo().to_chrome_json();
        assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        // Metadata first: process name, then one thread record per track.
        let meta = json.find("\"process_name\"").expect("process metadata");
        let t0 = json.find("\"device 0\"").expect("track 0 metadata");
        let first_span = json.find("\"ph\":\"X\"").expect("span");
        assert!(meta < t0 && t0 < first_span);
        // Instants render thread-scoped with their args.
        assert!(json.contains("\"ph\":\"i\",\"s\":\"t\""));
        assert!(json.contains("\"idle_cycles\":5"));
        // Virtual cycles render as bare numbers (1 cycle == 1 us).
        assert!(json.contains("\"ts\":0,\"dur\":10,\"cat\":\"job\""));
    }

    #[test]
    fn strings_are_escaped() {
        let mut tl = Timeline::new();
        let pid = tl.add_process("net\"x\\y");
        let mut buf = TrackBuffer::new(pid, 0);
        buf.span(0.0, 1.0, "h0 = rem/Wi \"q\"".into(), "job", 0, 0, vec![]);
        tl.merge(vec![buf]);
        let json = tl.to_chrome_json();
        assert!(json.contains("net\\\"x\\\\y"));
        assert!(json.contains("h0 = rem/Wi \\\"q\\\""));
    }
}
