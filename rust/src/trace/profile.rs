//! Wall-clock host profiler (the telemetry side of the two-clock rule,
//! DESIGN.md §16).
//!
//! Scoped timers + counters over the host hot paths: the plan-cache
//! build phases (shape / sparsity / tiling / strategy pricing) and DSE
//! candidate evaluation. Readings land in a global lock-free registry
//! of atomics — one slot per [`Phase`] with a call count, a running
//! nanosecond total, and a fixed log-scale duration histogram — that
//! [`snapshot`] copies out for the `repro profile` artifact and the
//! server's `/metrics` histograms.
//!
//! **This is the only module outside `src/server/` that may read the
//! host clock.** The `wall-clock-in-model` lint rule carves out exactly
//! this file (`src/trace/profile.rs`); instrumented call sites in
//! model code (`accel/plan.rs`, `dse/search.rs`) go through the opaque
//! [`scope`]/[`time`] helpers and never name `std::time` themselves.
//! Profiler readings are *telemetry*: they differ run to run by
//! construction and must never feed a byte-stable artifact — the lint
//! scoping makes that structural, not conventional.
//!
//! Overhead: one `Instant::now()` pair and three relaxed atomic adds
//! per scope (~100 ns), negligible next to a plan build (tens of
//! microseconds) and amortized to nothing under cache hits, which are
//! deliberately not instrumented.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// An instrumented host hot-path phase.
///
/// The three `Plan*` build sub-phases nest inside [`Phase::PlanBuild`]
/// (they partition one `LayerPlan::build`); [`Phase::PlanPricing`]
/// wraps the autotuner's whole candidate loop (so cached builds inside
/// it cost ~0); [`Phase::DseEvaluate`] wraps one DSE candidate
/// evaluation and therefore contains any cold builds it triggers.
/// Totals across phases overlap by design — compare within a level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// GEMM shape + packing derivation inside a plan build.
    PlanShape,
    /// Structural/data sparsity statistics inside a plan build.
    PlanSparsity,
    /// Tiling + prologue/stall modeling inside a plan build.
    PlanTiling,
    /// One whole cold `LayerPlan::build` (cache misses only).
    PlanBuild,
    /// One autotuner pricing pass over every lowering strategy.
    PlanPricing,
    /// One DSE candidate evaluation (objective over all layers).
    DseEvaluate,
}

impl Phase {
    /// Every phase, in rendering order.
    pub const ALL: [Phase; 6] = [
        Phase::PlanShape,
        Phase::PlanSparsity,
        Phase::PlanTiling,
        Phase::PlanBuild,
        Phase::PlanPricing,
        Phase::DseEvaluate,
    ];

    /// Stable snake-case name (artifact rows, `/metrics` labels).
    pub fn name(self) -> &'static str {
        match self {
            Phase::PlanShape => "plan_shape",
            Phase::PlanSparsity => "plan_sparsity",
            Phase::PlanTiling => "plan_tiling",
            Phase::PlanBuild => "plan_build",
            Phase::PlanPricing => "plan_pricing",
            Phase::DseEvaluate => "dse_evaluate",
        }
    }

    fn idx(self) -> usize {
        match self {
            Phase::PlanShape => 0,
            Phase::PlanSparsity => 1,
            Phase::PlanTiling => 2,
            Phase::PlanBuild => 3,
            Phase::PlanPricing => 4,
            Phase::DseEvaluate => 5,
        }
    }
}

/// Upper bounds (inclusive, nanoseconds) of the log-scale duration
/// histogram; the ninth bucket is the +Inf overflow. 1 us .. 1 s.
pub const NS_BUCKETS: [u64; 7] =
    [1_000, 10_000, 100_000, 1_000_000, 10_000_000, 100_000_000, 1_000_000_000];

/// Bucket count including the overflow bucket.
pub const BUCKETS: usize = NS_BUCKETS.len() + 1;

struct PhaseSlot {
    calls: AtomicU64,
    total_ns: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl PhaseSlot {
    const fn new() -> Self {
        Self {
            calls: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            buckets: [
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
                AtomicU64::new(0),
            ],
        }
    }

    fn record(&self, ns: u64) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.total_ns.fetch_add(ns, Ordering::Relaxed);
        let mut b = NS_BUCKETS.len();
        for i in 0..NS_BUCKETS.len() {
            if ns <= NS_BUCKETS[i] {
                b = i;
                break;
            }
        }
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
    }
}

static SLOTS: [PhaseSlot; 6] = [
    PhaseSlot::new(),
    PhaseSlot::new(),
    PhaseSlot::new(),
    PhaseSlot::new(),
    PhaseSlot::new(),
    PhaseSlot::new(),
];

/// An open scoped timer: started by [`scope`], recorded into the
/// registry when dropped (or handed to the next phase via
/// [`PhaseScope::next`], which records this phase and opens the next
/// back-to-back, sharing one clock read at the boundary).
pub struct PhaseScope {
    phase: Phase,
    start: Instant,
}

impl PhaseScope {
    /// Close this phase and immediately open `phase` at the same
    /// instant — for consecutive sub-phases of one computation.
    pub fn next(self, phase: Phase) -> PhaseScope {
        let now = Instant::now();
        record_ns(self.phase, now.duration_since(self.start).as_nanos() as u64);
        std::mem::forget(self);
        PhaseScope { phase, start: now }
    }
}

impl Drop for PhaseScope {
    fn drop(&mut self) {
        record_ns(self.phase, self.start.elapsed().as_nanos() as u64);
    }
}

/// Open a scoped timer for `phase`; it records when dropped.
pub fn scope(phase: Phase) -> PhaseScope {
    PhaseScope { phase, start: Instant::now() }
}

/// Time `f` under `phase` and return its result.
pub fn time<T>(phase: Phase, f: impl FnOnce() -> T) -> T {
    let _scope = scope(phase);
    f()
}

/// Record one observation directly (used by the scoped timers; public
/// so tests can seed deterministic readings).
pub fn record_ns(phase: Phase, ns: u64) {
    SLOTS[phase.idx()].record(ns);
}

/// Zero every counter (start of a `repro profile` measurement window).
pub fn reset() {
    for slot in &SLOTS {
        slot.calls.store(0, Ordering::Relaxed);
        slot.total_ns.store(0, Ordering::Relaxed);
        for bucket in &slot.buckets {
            bucket.store(0, Ordering::Relaxed);
        }
    }
}

/// Point-in-time copy of one phase's counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseStats {
    /// Observations recorded.
    pub calls: u64,
    /// Summed duration, nanoseconds.
    pub total_ns: u64,
    /// Per-bucket observation counts ([`NS_BUCKETS`] + overflow).
    pub buckets: [u64; BUCKETS],
}

impl PhaseStats {
    /// Mean duration in microseconds (0 when nothing was recorded).
    pub fn avg_us(&self) -> f64 {
        if self.calls == 0 {
            return 0.0;
        }
        self.total_ns as f64 / self.calls as f64 / 1_000.0
    }

    /// Observations per wall-clock second of summed phase time
    /// (0 when no time was recorded).
    pub fn per_sec(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        self.calls as f64 * 1e9 / self.total_ns as f64
    }
}

/// Point-in-time copy of the whole registry.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProfileSnapshot {
    /// Per-phase counters, indexed in [`Phase::ALL`] order.
    pub phases: [PhaseStats; 6],
}

impl ProfileSnapshot {
    /// Counters of `phase`.
    pub fn phase(&self, phase: Phase) -> &PhaseStats {
        &self.phases[phase.idx()]
    }

    /// Summed nanoseconds across every phase (phases overlap, so this
    /// is a weighting denominator for shares, not elapsed host time).
    pub fn total_ns(&self) -> u64 {
        self.phases.iter().map(|p| p.total_ns).sum()
    }
}

/// Copy the registry out (relaxed reads; counters move concurrently,
/// which is fine for telemetry).
pub fn snapshot() -> ProfileSnapshot {
    let mut snap = ProfileSnapshot::default();
    for i in 0..SLOTS.len() {
        snap.phases[i].calls = SLOTS[i].calls.load(Ordering::Relaxed);
        snap.phases[i].total_ns = SLOTS[i].total_ns.load(Ordering::Relaxed);
        for b in 0..BUCKETS {
            snap.phases[i].buckets[b] = SLOTS[i].buckets[b].load(Ordering::Relaxed);
        }
    }
    snap
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is global and the test binary is multi-threaded, so
    // every assertion here is on *deltas* of this test's own recordings
    // (other tests' instrumented plan builds may land concurrently) and
    // `reset` is never called outside a dedicated integration test.
    #[test]
    fn scoped_timers_accumulate_deltas() {
        let before = snapshot();
        let v = time(Phase::PlanPricing, || 21 * 2);
        assert_eq!(v, 42);
        record_ns(Phase::PlanPricing, 5_000); // bucket le=10us
        record_ns(Phase::PlanPricing, 2_000_000_000); // overflow bucket
        let after = snapshot();
        let d = |f: fn(&PhaseStats) -> u64| {
            f(after.phase(Phase::PlanPricing)) - f(before.phase(Phase::PlanPricing))
        };
        assert!(d(|p| p.calls) >= 3);
        assert!(d(|p| p.total_ns) >= 2_000_005_000);
        assert!(
            after.phase(Phase::PlanPricing).buckets[1] > before.phase(Phase::PlanPricing).buckets[1]
        );
        assert!(
            after.phase(Phase::PlanPricing).buckets[BUCKETS - 1]
                > before.phase(Phase::PlanPricing).buckets[BUCKETS - 1]
        );
    }

    #[test]
    fn next_closes_one_phase_and_opens_the_other() {
        let before = snapshot();
        let s = scope(Phase::PlanShape);
        let s = s.next(Phase::PlanTiling);
        drop(s);
        let after = snapshot();
        assert!(after.phase(Phase::PlanShape).calls > before.phase(Phase::PlanShape).calls);
        assert!(after.phase(Phase::PlanTiling).calls > before.phase(Phase::PlanTiling).calls);
    }

    #[test]
    fn derived_rates() {
        let s = PhaseStats { calls: 4, total_ns: 2_000_000, buckets: [0; BUCKETS] };
        assert!((s.avg_us() - 500.0).abs() < 1e-9);
        assert!((s.per_sec() - 2000.0).abs() < 1e-9);
        assert_eq!(PhaseStats::default().avg_us(), 0.0);
        assert_eq!(PhaseStats::default().per_sec(), 0.0);
    }

    #[test]
    fn phase_names_are_stable() {
        let names: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(
            names,
            [
                "plan_shape",
                "plan_sparsity",
                "plan_tiling",
                "plan_build",
                "plan_pricing",
                "dse_evaluate"
            ]
        );
        for i in 0..Phase::ALL.len() {
            assert_eq!(Phase::ALL[i].idx(), i);
        }
    }
}
