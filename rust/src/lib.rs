//! # bp-im2col
//!
//! Reproduction of **"BP-Im2col: Implicit Im2col Supporting AI
//! Backpropagation on Systolic Arrays"** (Yang et al., 2022).
//!
//! Backpropagation of a convolutional layer lowers to two extra GEMMs —
//! a *transposed* convolution for the loss of the input (`dX`) and a
//! *dilated* convolution for the gradient of the kernel (`dW`). Both
//! require zero-insertions (dilation by the forward stride) and
//! zero-paddings of the loss map; for `stride >= 2` the lowered matrices
//! are 75–94 % zeros. Traditional accelerators materialize those
//! zero-spaced tensors ("reorganization") in off-chip memory and stream
//! the zeros through the datapath. BP-im2col instead generates addresses
//! into the *compact* tensors on the fly (Algorithms 1 and 2 of the
//! paper), detects zero positions arithmetically, and moves only
//! non-zero data.
//!
//! The crate is organised in layers:
//!
//! * [`tensor`], [`conv`] — dense NCHW tensor substrate and a naive
//!   convolution fwd/bwd oracle (functional ground truth).
//! * [`im2col`] — the paper's contribution as *software*: explicit
//!   traditional lowering (with reorganization) and the implicit
//!   BP-im2col address mappings (Algorithm 1: transposed mode,
//!   Algorithm 2: dilated mode) plus NZ detection (Eqs. 2–4).
//! * [`sim`], [`accel`] — a cycle-level model of the paper's TPU-like
//!   accelerator: 16x16 input-stationary systolic array, double-buffered
//!   on-chip buffers, skew FIFOs, address-generation pipelines,
//!   compression + crossbar, DRAM, and the baseline's reorganization
//!   engine.
//! * [`workloads`] — the stride>=2 convolutional layers of the six CNNs
//!   the paper evaluates, plus dilated (DeepLab-style) and grouped
//!   (ResNeXt-style) networks exercising the generalized geometry
//!   (asymmetric strides, kernel dilation, channel groups — DESIGN.md
//!   §2–§3).
//! * [`coordinator`] — the training-job coordinator: queues per-layer
//!   backprop jobs, tiles them onto the accelerator, gathers metrics.
//!   Since coordinator v2 it plans each layer geometry **once** through
//!   a memoized plan cache (`accel::plan`) and can shard a backward
//!   pass across a **fleet** of simulated accelerators with work
//!   stealing (`coordinator::fleet`) — DESIGN.md §8.
//! * `runtime` — PJRT (xla crate) wrapper that loads the AOT-lowered
//!   JAX/Pallas HLO artifacts and runs them on the request path
//!   (behind the `pjrt` feature; the default build has no external
//!   dependencies).
//! * [`area`] — ASAP7-calibrated structural area model (Table IV).
//! * [`report`] — regenerates the numbers behind every table and figure
//!   of the paper.
//! * [`dse`] — design-space exploration over [`accel::AccelConfig`]:
//!   typed axes, seeded sampling + hill-climb refinement, exact Pareto
//!   frontiers over runtime/traffic/buffer/storage/area objectives,
//!   served like any other query (`repro dse`, `POST /v1/query` —
//!   DESIGN.md §11).
//! * [`api`] — the public query facade: typed [`api::SimRequest`]s
//!   served by an [`api::Service`] (shared plan cache, concurrent
//!   batches, per-request error isolation) into structured
//!   [`api::Artifact`]s with one text/CSV/JSON rendering layer — what
//!   the `repro` CLI and the server speak (DESIGN.md §9). The
//!   [`api::json`] submodule adds the request-side wire codec.
//! * [`server`] — a dependency-free HTTP/1.1 JSON frontend over the
//!   facade (`repro serve`): request framing with hard limits, a
//!   bounded worker pool, a rendered-response [`server::cache::ArtifactCache`]
//!   above the shared plan cache, `/metrics` observability and a
//!   signal-free graceful shutdown (DESIGN.md §10).
//! * [`lint`] — a std-only determinism & concurrency static analyzer
//!   for this crate's own sources (`repro lint`): six deny-by-default
//!   rules over a hand-rolled token-tree parse, suppressible only by
//!   reasoned in-source allows, gating CI (DESIGN.md §12).
//! * [`sparse`] — **data**-sparsity lowerings (DESIGN.md §14): the
//!   per-layer [`sparse::Density`] knob on [`ConvParams`], Kung-style
//!   column combining and a SPOTS-style sparse-GEMM pipeline as
//!   [`sparse::SparseLowering`] variants the plan builder evaluates
//!   next to the dense paths (`repro sparse`, `sim --density
//!   --lowering`, DSE `density`/`lowering` axes). The [`sparsity`]
//!   facade re-exports this alongside the paper's *structural*
//!   zero-space closed forms so the two notions can't be confused.
//! * [`trace`] — observability under the two-clock rule (DESIGN.md
//!   §16): deterministic *virtual-time* timelines over the fleet
//!   replay (Chrome trace-event JSON, `repro trace`, byte-identical
//!   across device widths and frontends) strictly separated from the
//!   *wall-clock* host profiler over the plan-build and DSE hot paths
//!   (`repro profile`, `/metrics` histograms — telemetry, never
//!   cached, lint-enforced to stay out of model code).
//! * `accel::strategy` + the plan-cache autotuner (DESIGN.md §15) —
//!   the lowering dataflow as a first-class axis: the paper's two
//!   strategies plus two EcoFlow-style scatter dataflows behind one
//!   [`accel::strategy::LoweringStrategy`] family, a deterministic
//!   per-layer autotuner (`--lowering-strategy auto`, `repro
//!   autotune`) that prices every candidate through the shared plan
//!   cache and records the mix it chose.
//!
//! See the top-level `README.md` for a quickstart and the full CLI
//! command table, `DESIGN.md` for modeling decisions, and
//! `EXPERIMENTS.md` for measured results and deltas vs the paper.

#![warn(missing_docs)]

pub mod accel;
pub mod api;
pub mod area;
pub mod conv;
pub mod coordinator;
pub mod dse;
pub mod im2col;
pub mod lint;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod sim;
pub mod sparse;
pub mod sparsity;
pub mod tensor;
pub mod trace;
pub mod workloads;

pub use api::{Artifact, Service, SimRequest};
pub use conv::ConvParams;
pub use tensor::Tensor4;
